//! The DiCoDiLe-Z leader: spawns the worker grid, runs the
//! counter-based termination protocol, and gathers the solution.
//!
//! The coordinator never touches beta or Z during the solve — all
//! hot-path traffic is worker-to-worker — it only observes status
//! transitions. Global convergence is declared when every worker
//! reports idle *and* the total number of update messages sent equals
//! the total received (Safra-style counting: no messages in flight, so
//! no worker can be re-activated).

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::csc::problem::CscProblem;
use crate::dicod::config::DicodConfig;
use crate::dicod::messages::{CoordMsg, WorkerMsg, WorkerStats};
use crate::dicod::partition::WorkerGrid;
use crate::dicod::worker::{run_worker, Peer, WorkerCtx};
use crate::tensor::NdTensor;

/// Aggregated result of a distributed solve.
#[derive(Clone, Debug)]
pub struct DicodResult {
    pub z: NdTensor,
    pub converged: bool,
    pub diverged: bool,
    pub runtime: f64,
    pub n_workers: usize,
    /// Summed worker counters.
    pub stats: WorkerStats,
    pub per_worker: Vec<WorkerStats>,
}

impl DicodResult {
    /// The busiest worker's clock in abstract work units — the
    /// simulated parallel makespan on a machine with one core per
    /// worker. This testbed has a single physical core, so the scaling
    /// figures (paper Figs. 4, 6, C.1, C.2) are reported in this
    /// simulated-time model; wall-clock is also recorded for reference.
    pub fn critical_path_work(&self) -> u64 {
        self.per_worker.iter().map(|s| s.work).max().unwrap_or(0)
    }

    /// Total work across workers (the sequential-equivalent clock).
    pub fn total_work(&self) -> u64 {
        self.per_worker.iter().map(|s| s.work).sum()
    }

    /// Simulated parallel time in seconds, calibrated with a measured
    /// per-unit cost (seconds per work unit).
    pub fn simulated_time(&self, secs_per_unit: f64) -> f64 {
        self.critical_path_work() as f64 * secs_per_unit
    }
}

/// Solve the CSC problem with `cfg.n_workers` asynchronous workers.
pub fn solve_distributed(problem: &CscProblem, cfg: &DicodConfig) -> DicodResult {
    let start = Instant::now();
    let zsp = problem.z_spatial_dims();
    let grid = WorkerGrid::new(&zsp, problem.atom_dims(), cfg.n_workers, cfg.partition);
    let w_tot = grid.n_workers();

    // Build the channel mesh.
    let mut worker_tx = Vec::with_capacity(w_tot);
    let mut worker_rx = Vec::with_capacity(w_tot);
    for _ in 0..w_tot {
        let (tx, rx) = mpsc::channel::<WorkerMsg>();
        worker_tx.push(tx);
        worker_rx.push(rx);
    }
    let (coord_tx, coord_rx) = mpsc::channel::<CoordMsg>();

    let mut result: Option<DicodResult> = None;
    std::thread::scope(|scope| {
        // Spawn workers.
        for (rank, rx) in worker_rx.drain(..).enumerate() {
            let peers: Vec<Peer> = grid
                .neighbors(rank)
                .into_iter()
                .map(|r| Peer {
                    rank: r,
                    ext_window: grid.extended_cell(r),
                    tx: worker_tx[r].clone(),
                })
                .collect();
            let ctx = WorkerCtx {
                rank,
                problem,
                grid: &grid,
                cfg,
                inbox: rx,
                peers,
                coord: coord_tx.clone(),
            };
            scope.spawn(move || run_worker(ctx));
        }
        drop(coord_tx);

        // ---- supervision loop -------------------------------------------
        let mut idle = vec![false; w_tot];
        let mut converged = vec![false; w_tot];
        let mut sent = vec![0u64; w_tot];
        let mut received = vec![0u64; w_tot];
        let mut any_diverged = false;
        let mut stop_sent = false;
        let mut done: Vec<Option<(Vec<f64>, WorkerStats)>> = vec![None; w_tot];
        let mut n_done = 0usize;
        let deadline = Instant::now() + Duration::from_secs_f64(cfg.timeout);

        let broadcast_stop = |worker_tx: &[mpsc::Sender<WorkerMsg>]| {
            for tx in worker_tx {
                let _ = tx.send(WorkerMsg::Stop);
            }
        };

        while n_done < w_tot {
            let msg = coord_rx.recv_timeout(Duration::from_millis(20));
            match msg {
                Ok(CoordMsg::Status(s)) => {
                    idle[s.from] = s.idle;
                    converged[s.from] = s.converged;
                    sent[s.from] = s.sent;
                    received[s.from] = s.received;
                    if s.diverged {
                        any_diverged = true;
                    }
                    let all_idle = idle.iter().all(|&b| b);
                    let balanced =
                        sent.iter().sum::<u64>() == received.iter().sum::<u64>();
                    if !stop_sent && (any_diverged || (all_idle && balanced)) {
                        stop_sent = true;
                        broadcast_stop(&worker_tx);
                    }
                }
                Ok(CoordMsg::Done(d)) => {
                    if done[d.from].is_none() {
                        n_done += 1;
                    }
                    done[d.from] = Some((d.z_cell, d.stats));
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
            if !stop_sent && Instant::now() > deadline {
                stop_sent = true;
                broadcast_stop(&worker_tx);
            }
        }

        // ---- assemble Z ---------------------------------------------------
        let k_tot = problem.n_atoms();
        let mut z = NdTensor::zeros(&problem.z_dims());
        let zstr = crate::tensor::shape::strides_of(&zsp);
        let sp: usize = zsp.iter().product();
        let mut per_worker = Vec::with_capacity(w_tot);
        let mut agg = WorkerStats::default();
        for (rank, slot) in done.iter().enumerate() {
            let Some((cell_z, stats)) = slot else {
                per_worker.push(WorkerStats::default());
                continue;
            };
            let cell = grid.cell(rank);
            let cell_sp = cell.size();
            for k in 0..k_tot {
                for (i, u) in cell.iter().enumerate() {
                    let goff: usize =
                        u.iter().zip(&zstr).map(|(x, s)| *x as usize * s).sum();
                    z.data_mut()[k * sp + goff] = cell_z[k * cell_sp + i];
                }
            }
            agg.merge(stats);
            per_worker.push(stats.clone());
        }

        result = Some(DicodResult {
            z,
            converged: converged.iter().all(|&b| b) && !any_diverged,
            diverged: any_diverged,
            runtime: start.elapsed().as_secs_f64(),
            n_workers: w_tot,
            stats: agg,
            per_worker,
        });
    });

    result.expect("coordinator always produces a result")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csc::cd::{kkt_violation, solve_cd, CdConfig};
    use crate::csc::select::Strategy;
    use crate::dicod::partition::PartitionKind;
    use crate::util::rng::Pcg64;

    fn gen_problem_1d(seed: u64, t: usize, k: usize, l: usize) -> CscProblem {
        let mut rng = Pcg64::seeded(seed);
        let d = NdTensor::from_vec(&[k, 1, l], {
            let mut v = rng.normal_vec(k * l);
            for atom in v.chunks_mut(l) {
                let n = atom.iter().map(|x| x * x).sum::<f64>().sqrt();
                for x in atom.iter_mut() {
                    *x /= n;
                }
            }
            v
        });
        let mut z = NdTensor::zeros(&[k, t - l + 1]);
        for v in z.data_mut().iter_mut() {
            if rng.bernoulli(0.03) {
                *v = rng.normal_ms(0.0, 5.0);
            }
        }
        let clean = crate::conv::reconstruct(&z, &d);
        let noise =
            NdTensor::from_vec(clean.dims(), rng.normal_vec(clean.len())).scale(0.1);
        CscProblem::with_lambda_frac(clean.add(&noise), d, 0.1)
    }

    fn gen_problem_2d(seed: u64, h: usize, w: usize, k: usize, l: usize) -> CscProblem {
        let mut rng = Pcg64::seeded(seed);
        let d = NdTensor::from_vec(&[k, 1, l, l], {
            let mut v = rng.normal_vec(k * l * l);
            for atom in v.chunks_mut(l * l) {
                let n = atom.iter().map(|x| x * x).sum::<f64>().sqrt();
                for x in atom.iter_mut() {
                    *x /= n;
                }
            }
            v
        });
        let mut z = NdTensor::zeros(&[k, h - l + 1, w - l + 1]);
        for v in z.data_mut().iter_mut() {
            if rng.bernoulli(0.02) {
                *v = rng.normal_ms(0.0, 5.0);
            }
        }
        let clean = crate::conv::reconstruct(&z, &d);
        let noise =
            NdTensor::from_vec(clean.dims(), rng.normal_vec(clean.len())).scale(0.1);
        CscProblem::with_lambda_frac(clean.add(&noise), d, 0.1)
    }

    #[test]
    fn distributed_matches_sequential_1d() {
        let p = gen_problem_1d(1, 150, 3, 6);
        let seq = solve_cd(&p, &CdConfig { tol: 1e-8, ..Default::default() });
        for w in [1usize, 2, 4] {
            let cfg = DicodConfig { n_workers: w, tol: 1e-8, ..Default::default() };
            let r = solve_distributed(&p, &cfg);
            assert!(r.converged, "W={w} did not converge");
            let cd = p.cost(&r.z);
            let cs = p.cost(&seq.z);
            assert!(
                (cd - cs).abs() < 1e-6 * (1.0 + cs.abs()),
                "W={w}: distributed cost {cd} vs sequential {cs}"
            );
            assert!(kkt_violation(&p, &r.z) < 1e-6, "W={w} KKT violated");
        }
    }

    #[test]
    fn distributed_matches_sequential_2d_grid() {
        let p = gen_problem_2d(2, 24, 24, 2, 4);
        let seq = solve_cd(&p, &CdConfig { tol: 1e-8, ..Default::default() });
        let cs = p.cost(&seq.z);
        for w in [1usize, 4] {
            let cfg = DicodConfig {
                n_workers: w,
                partition: PartitionKind::Grid,
                tol: 1e-8,
                ..Default::default()
            };
            let r = solve_distributed(&p, &cfg);
            assert!(r.converged, "W={w}");
            let cd = p.cost(&r.z);
            assert!(
                (cd - cs).abs() < 1e-6 * (1.0 + cs.abs()),
                "W={w}: {cd} vs {cs}"
            );
        }
    }

    #[test]
    fn dicod_baseline_converges_1d() {
        let p = gen_problem_1d(3, 120, 2, 5);
        let r = solve_distributed(&p, &DicodConfig { tol: 1e-7, ..DicodConfig::dicod(3) });
        assert!(r.converged);
        assert!(kkt_violation(&p, &r.z) < 1e-5);
    }

    #[test]
    fn grid_4_workers_2d_uses_2x2() {
        let p = gen_problem_2d(4, 20, 20, 2, 3);
        let cfg = DicodConfig { n_workers: 4, tol: 1e-7, ..Default::default() };
        let r = solve_distributed(&p, &cfg);
        assert_eq!(r.n_workers, 4);
        assert!(r.converged);
    }

    #[test]
    fn stats_are_aggregated() {
        let p = gen_problem_1d(5, 100, 2, 5);
        let r = solve_distributed(&p, &DicodConfig { n_workers: 2, ..Default::default() });
        assert_eq!(r.per_worker.len(), 2);
        assert_eq!(
            r.stats.updates,
            r.per_worker.iter().map(|s| s.updates).sum::<u64>()
        );
        assert!(r.stats.updates > 0);
    }

    #[test]
    fn messages_flow_between_neighbors() {
        // A signal with structure across the split boundary forces
        // cross-worker notifications.
        let p = gen_problem_1d(6, 100, 2, 8);
        let r = solve_distributed(&p, &DicodConfig { n_workers: 4, tol: 1e-8, ..Default::default() });
        assert!(r.converged);
        assert!(r.stats.msgs_sent > 0, "expected border traffic");
        assert_eq!(r.stats.msgs_sent, r.stats.msgs_received);
    }

    #[test]
    fn single_worker_equals_sequential_lgcd() {
        let p = gen_problem_1d(7, 80, 2, 5);
        let seq = solve_cd(
            &p,
            &CdConfig { strategy: Strategy::LocallyGreedy, tol: 1e-9, ..Default::default() },
        );
        let r = solve_distributed(&p, &DicodConfig { n_workers: 1, tol: 1e-9, ..Default::default() });
        assert!(r.converged);
        // identical domain order -> identical fixed point
        assert!(r.z.allclose(&seq.z, 1e-7));
    }
}
