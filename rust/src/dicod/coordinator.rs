//! One-shot entry points over the resident [`WorkerPool`].
//!
//! `solve_distributed` spawns a temporary pool, runs a single solve
//! phase, gathers Z and tears the pool down — the ephemeral mode every
//! single-solve caller (benches, `sparse_encode`) uses. The CDL driver
//! keeps the pool alive across the whole alternation instead; see
//! [`crate::dicod::pool`].
//!
//! The coordinator side never touches beta or Z during a solve — all
//! hot-path traffic is worker-to-worker — it only observes status
//! transitions. Global convergence is declared when every worker
//! reports idle *and* the total number of update messages sent equals
//! the total received (Safra-style counting: no messages in flight, so
//! no worker can be re-activated). Which wire carries those messages is
//! the pool's transport's business (`DicodConfig::transport`): the
//! supervision logic here is transport-agnostic and byte-for-byte
//! identical over channels and sockets.

use std::sync::Arc;
use std::time::Instant;

use crate::csc::problem::CscProblem;
use crate::dicod::config::DicodConfig;
use crate::dicod::messages::WorkerStats;
use crate::dicod::pool::WorkerPool;
use crate::tensor::NdTensor;

/// Aggregated result of a distributed solve.
#[derive(Clone, Debug)]
pub struct DicodResult {
    pub z: NdTensor,
    pub converged: bool,
    pub diverged: bool,
    pub runtime: f64,
    pub n_workers: usize,
    /// Summed worker counters.
    pub stats: WorkerStats,
    pub per_worker: Vec<WorkerStats>,
}

impl DicodResult {
    /// The busiest worker's clock in abstract work units — the
    /// simulated parallel makespan on a machine with one core per
    /// worker. This testbed has a single physical core, so the scaling
    /// figures (paper Figs. 4, 6, C.1, C.2) are reported in this
    /// simulated-time model; wall-clock is also recorded for reference.
    pub fn critical_path_work(&self) -> u64 {
        self.per_worker.iter().map(|s| s.work).max().unwrap_or(0)
    }

    /// Total work across workers (the sequential-equivalent clock).
    pub fn total_work(&self) -> u64 {
        self.per_worker.iter().map(|s| s.work).sum()
    }

    /// Simulated parallel time in seconds, calibrated with a measured
    /// per-unit cost (seconds per work unit).
    pub fn simulated_time(&self, secs_per_unit: f64) -> f64 {
        self.critical_path_work() as f64 * secs_per_unit
    }
}

/// Solve the CSC problem with `cfg.n_workers` asynchronous workers,
/// cold-starting from `Z = 0`.
pub fn solve_distributed(problem: &CscProblem, cfg: &DicodConfig) -> DicodResult {
    solve_distributed_warm(problem, cfg, None)
}

/// Solve with an optional full-domain warm-start activation: each
/// worker loads its window slice of `z0` and bootstraps beta warm, so
/// an outer loop that cannot keep a pool alive still avoids replaying
/// converged coordinates from zero.
pub fn solve_distributed_warm(
    problem: &CscProblem,
    cfg: &DicodConfig,
    z0: Option<&NdTensor>,
) -> DicodResult {
    let start = Instant::now();
    let mut pool = WorkerPool::spawn(Arc::new(problem.clone()), cfg, z0);
    let phase = pool.solve();
    let z = pool.gather();
    let result = DicodResult {
        z,
        converged: phase.converged,
        diverged: phase.diverged,
        runtime: start.elapsed().as_secs_f64(),
        n_workers: pool.n_workers(),
        stats: pool.aggregate_stats(),
        per_worker: pool.per_worker().to_vec(),
    };
    pool.shutdown();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csc::cd::{kkt_violation, solve_cd, CdConfig};
    use crate::csc::select::Strategy;
    use crate::dicod::partition::PartitionKind;
    use crate::util::rng::Pcg64;

    fn gen_problem_1d(seed: u64, t: usize, k: usize, l: usize) -> CscProblem {
        let mut rng = Pcg64::seeded(seed);
        let d = NdTensor::from_vec(&[k, 1, l], {
            let mut v = rng.normal_vec(k * l);
            for atom in v.chunks_mut(l) {
                let n = atom.iter().map(|x| x * x).sum::<f64>().sqrt();
                for x in atom.iter_mut() {
                    *x /= n;
                }
            }
            v
        });
        let mut z = NdTensor::zeros(&[k, t - l + 1]);
        for v in z.data_mut().iter_mut() {
            if rng.bernoulli(0.03) {
                *v = rng.normal_ms(0.0, 5.0);
            }
        }
        let clean = crate::conv::reconstruct(&z, &d);
        let noise =
            NdTensor::from_vec(clean.dims(), rng.normal_vec(clean.len())).scale(0.1);
        CscProblem::with_lambda_frac(clean.add(&noise), d, 0.1)
    }

    fn gen_problem_2d(seed: u64, h: usize, w: usize, k: usize, l: usize) -> CscProblem {
        let mut rng = Pcg64::seeded(seed);
        let d = NdTensor::from_vec(&[k, 1, l, l], {
            let mut v = rng.normal_vec(k * l * l);
            for atom in v.chunks_mut(l * l) {
                let n = atom.iter().map(|x| x * x).sum::<f64>().sqrt();
                for x in atom.iter_mut() {
                    *x /= n;
                }
            }
            v
        });
        let mut z = NdTensor::zeros(&[k, h - l + 1, w - l + 1]);
        for v in z.data_mut().iter_mut() {
            if rng.bernoulli(0.02) {
                *v = rng.normal_ms(0.0, 5.0);
            }
        }
        let clean = crate::conv::reconstruct(&z, &d);
        let noise =
            NdTensor::from_vec(clean.dims(), rng.normal_vec(clean.len())).scale(0.1);
        CscProblem::with_lambda_frac(clean.add(&noise), d, 0.1)
    }

    #[test]
    fn distributed_matches_sequential_1d() {
        let p = gen_problem_1d(1, 150, 3, 6);
        let seq = solve_cd(&p, &CdConfig { tol: 1e-8, ..Default::default() });
        for w in [1usize, 2, 4] {
            let cfg = DicodConfig { n_workers: w, tol: 1e-8, ..Default::default() };
            let r = solve_distributed(&p, &cfg);
            assert!(r.converged, "W={w} did not converge");
            let cd = p.cost(&r.z);
            let cs = p.cost(&seq.z);
            assert!(
                (cd - cs).abs() < 1e-6 * (1.0 + cs.abs()),
                "W={w}: distributed cost {cd} vs sequential {cs}"
            );
            assert!(kkt_violation(&p, &r.z) < 1e-6, "W={w} KKT violated");
        }
    }

    #[test]
    fn distributed_matches_sequential_2d_grid() {
        let p = gen_problem_2d(2, 24, 24, 2, 4);
        let seq = solve_cd(&p, &CdConfig { tol: 1e-8, ..Default::default() });
        let cs = p.cost(&seq.z);
        for w in [1usize, 4] {
            let cfg = DicodConfig {
                n_workers: w,
                partition: PartitionKind::Grid,
                tol: 1e-8,
                ..Default::default()
            };
            let r = solve_distributed(&p, &cfg);
            assert!(r.converged, "W={w}");
            let cd = p.cost(&r.z);
            assert!(
                (cd - cs).abs() < 1e-6 * (1.0 + cs.abs()),
                "W={w}: {cd} vs {cs}"
            );
        }
    }

    #[test]
    fn dicod_baseline_converges_1d() {
        let p = gen_problem_1d(3, 120, 2, 5);
        let r = solve_distributed(&p, &DicodConfig { tol: 1e-7, ..DicodConfig::dicod(3) });
        assert!(r.converged);
        assert!(kkt_violation(&p, &r.z) < 1e-5);
    }

    #[test]
    fn grid_4_workers_2d_uses_2x2() {
        let p = gen_problem_2d(4, 20, 20, 2, 3);
        let cfg = DicodConfig { n_workers: 4, tol: 1e-7, ..Default::default() };
        let r = solve_distributed(&p, &cfg);
        assert_eq!(r.n_workers, 4);
        assert!(r.converged);
    }

    #[test]
    fn stats_are_aggregated() {
        let p = gen_problem_1d(5, 100, 2, 5);
        let r = solve_distributed(&p, &DicodConfig { n_workers: 2, ..Default::default() });
        assert_eq!(r.per_worker.len(), 2);
        assert_eq!(
            r.stats.updates,
            r.per_worker.iter().map(|s| s.updates).sum::<u64>()
        );
        assert!(r.stats.updates > 0);
    }

    #[test]
    fn messages_flow_between_neighbors() {
        // A signal with structure across the split boundary forces
        // cross-worker notifications.
        let p = gen_problem_1d(6, 100, 2, 8);
        let r = solve_distributed(&p, &DicodConfig { n_workers: 4, tol: 1e-8, ..Default::default() });
        assert!(r.converged);
        assert!(r.stats.msgs_sent > 0, "expected border traffic");
        assert_eq!(r.stats.msgs_sent, r.stats.msgs_received);
    }

    #[test]
    fn single_worker_equals_sequential_lgcd() {
        let p = gen_problem_1d(7, 80, 2, 5);
        let seq = solve_cd(
            &p,
            &CdConfig { strategy: Strategy::LocallyGreedy, tol: 1e-9, ..Default::default() },
        );
        let r = solve_distributed(&p, &DicodConfig { n_workers: 1, tol: 1e-9, ..Default::default() });
        assert!(r.converged);
        // identical domain order -> identical fixed point
        assert!(r.z.allclose(&seq.z, 1e-7));
    }

    #[test]
    fn warm_start_at_optimum_is_a_noop() {
        let p = gen_problem_1d(8, 130, 2, 6);
        let cold = solve_distributed(&p, &DicodConfig { n_workers: 3, tol: 1e-8, ..Default::default() });
        assert!(cold.converged);
        let warm = solve_distributed_warm(
            &p,
            &DicodConfig { n_workers: 3, tol: 1e-7, ..Default::default() },
            Some(&cold.z),
        );
        assert!(warm.converged);
        assert_eq!(warm.stats.updates, 0, "warm start at the optimum must do nothing");
        assert_eq!(warm.stats.beta_warm_inits, 3);
        assert_eq!(warm.stats.beta_cold_inits, 0);
        assert!(warm.z.allclose(&cold.z, 1e-12));
    }

    #[test]
    fn warm_start_from_partial_solution_converges() {
        // Warm-start from a loosely-converged Z and re-solve tightly.
        let p = gen_problem_1d(9, 140, 2, 6);
        let rough = solve_distributed(&p, &DicodConfig { n_workers: 2, tol: 1e-2, ..Default::default() });
        let tight = solve_distributed_warm(
            &p,
            &DicodConfig { n_workers: 2, tol: 1e-8, ..Default::default() },
            Some(&rough.z),
        );
        assert!(tight.converged);
        let seq = solve_cd(&p, &CdConfig { tol: 1e-8, ..Default::default() });
        let (cw, cs) = (p.cost(&tight.z), p.cost(&seq.z));
        assert!((cw - cs).abs() < 1e-6 * (1.0 + cs.abs()), "{cw} vs {cs}");
    }
}
