//! Messages exchanged by the distributed solver.
//!
//! Workers talk to their grid neighbours (coordinate-update
//! notifications, the only hot-path traffic) and to the coordinator
//! (status transitions for the termination protocol). There is no
//! central data server: the coordinator never sees beta or Z until the
//! final gather, mirroring the paper's decentralized design.

/// A coordinate update notification `(k0, u0, dZ)` (§4.1, Fig. 2).
#[derive(Clone, Debug, PartialEq)]
pub struct UpdateMsg {
    pub from: usize,
    pub k: usize,
    pub u: Vec<i64>,
    pub dz: f64,
}

/// Worker -> worker traffic.
#[derive(Clone, Debug)]
pub enum WorkerMsg {
    /// A neighbour changed a coordinate whose V-box reaches our window.
    Update(UpdateMsg),
    /// Coordinator: stop now and report results.
    Stop,
}

/// Worker status transition, carrying message counters for the
/// Safra-style termination detection: global convergence holds when
/// every worker is idle and `sum(sent) == sum(received)` (no messages
/// in flight).
#[derive(Clone, Debug)]
pub struct StatusMsg {
    pub from: usize,
    pub idle: bool,
    pub sent: u64,
    pub received: u64,
    /// Worker believes it converged locally (vs hit its update cap).
    pub converged: bool,
    /// Divergence guard tripped.
    pub diverged: bool,
}

/// Final per-worker report.
#[derive(Clone, Debug)]
pub struct DoneMsg {
    pub from: usize,
    /// Flat activation values over the worker's own cell `S_w`
    /// (row-major over `[K, cell extents..]`).
    pub z_cell: Vec<f64>,
    pub stats: WorkerStats,
}

/// Worker -> coordinator traffic.
#[derive(Clone, Debug)]
pub enum CoordMsg {
    Status(StatusMsg),
    Done(DoneMsg),
}

/// Per-worker work counters.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Selection iterations (segments visited).
    pub iterations: u64,
    /// Accepted coordinate updates.
    pub updates: u64,
    /// Candidates rejected by the soft-lock.
    pub soft_locked: u64,
    /// Update messages sent to neighbours.
    pub msgs_sent: u64,
    /// Update messages received.
    pub msgs_received: u64,
    /// Full sweeps over the local segments.
    pub sweeps: u64,
    /// Times the worker paused (went idle).
    pub pauses: u64,
    /// Abstract work units (coordinates scanned + beta entries touched):
    /// the per-worker clock of the simulated-time model used for the
    /// scaling figures (this testbed has a single physical core, so
    /// parallel wall-clock cannot be measured directly — see DESIGN.md).
    pub work: u64,
}

impl WorkerStats {
    pub fn merge(&mut self, other: &WorkerStats) {
        self.iterations += other.iterations;
        self.updates += other.updates;
        self.soft_locked += other.soft_locked;
        self.msgs_sent += other.msgs_sent;
        self.msgs_received += other.msgs_received;
        self.sweeps += other.sweeps;
        self.pauses += other.pauses;
        self.work += other.work;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_merge() {
        let mut a = WorkerStats { updates: 3, msgs_sent: 1, ..Default::default() };
        let b = WorkerStats { updates: 4, soft_locked: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.updates, 7);
        assert_eq!(a.soft_locked, 2);
        assert_eq!(a.msgs_sent, 1);
    }
}
