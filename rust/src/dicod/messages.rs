//! Messages exchanged by the distributed runtime, plus their wire
//! forms.
//!
//! Workers talk to their grid neighbours (coordinate-update
//! notifications, the only hot-path traffic) and to the coordinator.
//! There is no central data server: the coordinator never sees beta or
//! Z until an explicit `Gather`, mirroring the paper's decentralized
//! design (§4.2) — between CDL alternations only status transitions,
//! phase commands and the (signal-size-independent) φ/ψ partials flow.
//!
//! ## Phase protocol (persistent pool)
//!
//! The pool drives resident workers through phases. Delivery goes
//! through the transport seam ([`crate::dicod::transport`]): in-process
//! channels move the in-memory types below directly, while the socket
//! transport moves the length-prefixed wire frames in the last column.
//!
//! | command        | worker reply           | effect                              | wire frame                          |
//! |----------------|------------------------|-------------------------------------|-------------------------------------|
//! | `Solve`        | `Status`… `SolveDone`  | run DiCoDiLe-Z from the resident Z  | tag only / status + 17 counters     |
//! | `Stop`         | (ends the solve phase) | sent by the pool on convergence     | tag only                            |
//! | `ComputeStats` | `Stats`                | local φ^w/ψ^w partials (eq. 17)     | tag / two tensors + `z_l1`, `z_nnz` |
//! | `SetDict`      | `DictSet`              | swap D, warm beta re-init from Z    | [`DictUpdate`] (D + λ + fingerprint)|
//! | `SetProblem`   | `ProblemSet`           | swap X *and* D (streaming chunks)   | [`ProblemUpdate`] (X + D + λ + Z0)  |
//! | `ResumeSolve`  | `Status`… `SolveDone`  | re-enter the solve loop in place    | tag only                            |
//! | `Gather`       | `Done`                 | report the cell's activation values | tag / flat cell values + counters   |
//! | `Shutdown`     | (thread exits)         |                                     | tag only                            |
//!
//! `ResumeSolve` is the pipelined-alternation leg: after shipping its
//! φ/ψ partial the worker resumes coordinate descent *speculatively
//! under the old dictionary* (its resident Z/beta are at the previous
//! fixed point, so the speculative updates are ordinary warm progress)
//! while the coordinator runs the dictionary PGD. The subsequent
//! `SetDict` then lands *mid-solve* and is applied as the usual warm
//! beta re-init without leaving the Solve phase. Under the default
//! `Barrier` alternation neither mid-solve leg ever fires.
//!
//! Neighbour `Update` notifications ride the same seam: in channel mode
//! a direct send into the destination inbox, in socket mode a `Fwd`
//! frame routed through the coordinator-side hub.
//!
//! ## SetDict across the seam
//!
//! The in-process broadcast ships `Arc<CscProblem>` clones, so all
//! workers share one correlation engine and its spectra cache — the
//! spectra are regenerated once per broadcast. An `Arc` cannot cross a
//! process boundary, so the wire form is a [`DictUpdate`] (dictionary
//! tensor + λ + geometry fingerprint) and each receiving endpoint
//! rebuilds a local `CscProblem` from its resident X: the derived
//! quantities are bit-identical (deterministic construction), but the
//! spectra are regenerated once per *host*, not once per broadcast.
//!
//! Counter rules between phases: the Safra counters (`sent` /
//! `received`) are *cumulative over the pool's lifetime* — a
//! notification that is still queued when a solve phase ends is applied
//! (and counted received) while the worker idles between phases, so the
//! global balance `sum(sent) == sum(received)` always settles before
//! the next solve begins and the termination detection never sees a
//! phantom in-flight message. Per-solve state (update cap, divergence
//! flag, sweep position, deadline) resets at every `Solve`.
//!
//! ## Wire format
//!
//! Frames on a socket are `u32` little-endian length + payload; the
//! payload is a tag byte followed by fixed-order fields. Integers are
//! 64-bit little-endian, `f64`s travel as their IEEE-754 bit patterns
//! (`to_bits`, so round-trips are exact and NaN-safe), vectors as a
//! `u64` count + elements, tensors as rank + dims + data. Decoding is
//! strict: unknown tags, truncated payloads, non-canonical booleans and
//! trailing bytes are all rejected with a [`WireError`] rather than
//! silently tolerated.

use std::sync::Arc;

use crate::csc::problem::CscProblem;
use crate::tensor::NdTensor;

/// A coordinate update notification `(k0, u0, dZ)` (§4.1, Fig. 2).
#[derive(Clone, Debug, PartialEq)]
pub struct UpdateMsg {
    pub from: usize,
    pub k: usize,
    pub u: Vec<i64>,
    pub dz: f64,
}

/// Serializable dictionary broadcast: what actually crosses a process
/// boundary on `SetDict`. Carries the new dictionary tensor and λ plus
/// a fingerprint of the problem geometry, so a remote worker can refuse
/// a dictionary that was meant for a different problem instead of
/// rebuilding garbage.
#[derive(Clone, Debug)]
pub struct DictUpdate {
    /// The new dictionary `[K, P, L..]`.
    pub d: NdTensor,
    /// The (absolute) regularization weight.
    pub lambda: f64,
    /// FNV-1a over the X and D dims — must match
    /// [`DictUpdate::geometry_fingerprint`] of the receiving worker's
    /// resident problem.
    pub fingerprint: u64,
}

impl DictUpdate {
    /// Wire form of a problem's dictionary state.
    pub fn from_problem(p: &CscProblem) -> Self {
        DictUpdate {
            d: p.d.clone(),
            lambda: p.lambda,
            fingerprint: Self::geometry_fingerprint(p.x.dims(), p.d.dims()),
        }
    }

    /// Cheap identity of the problem geometry (FNV-1a over the X and D
    /// dims). This is deliberately shape-only: the X *values* live with
    /// the worker and never travel on `SetDict`.
    pub fn geometry_fingerprint(x_dims: &[usize], d_dims: &[usize]) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        for &d in x_dims.iter().chain(d_dims) {
            h = (h ^ (d as u64)).wrapping_mul(PRIME);
        }
        h
    }
}

/// Dictionary broadcast. The in-process transport ships `Shared` —
/// clones of one `Arc`, so all workers share one correlation engine and
/// its spectra cache (regenerated once per broadcast, by whichever
/// worker bootstraps first). The socket transport encodes `Shared` down
/// to its [`DictUpdate`] and delivers `Wire`; the receiving worker
/// rebuilds a local `CscProblem` against its resident X (bit-identical
/// derived quantities, spectra regenerated once per host).
#[derive(Clone, Debug)]
pub enum SetDictMsg {
    /// Same-process broadcast: the rebuilt problem (same shared X, new
    /// D and derived quantities).
    Shared(Arc<CscProblem>),
    /// Cross-process broadcast: rebuild locally from the resident X.
    Wire(DictUpdate),
}

/// Serializable problem swap: what crosses a process boundary on
/// `SetProblem`. Unlike [`DictUpdate`] this carries the observation
/// itself — the streaming encoder re-targets a resident grid at a new
/// signal window every chunk, so the resident X is *wrong*, not merely
/// stale. The optional `z0` warm-starts the activation window (the
/// stitching holdback carried over from the previous chunk).
#[derive(Clone, Debug)]
pub struct ProblemUpdate {
    /// The new observation `[P, T..]` (same dims as the resident one).
    pub x: NdTensor,
    /// The dictionary `[K, P, L..]`.
    pub d: NdTensor,
    /// The (absolute) regularization weight.
    pub lambda: f64,
    /// Optional full-domain warm-start activation `[K, T'..]`.
    pub z0: Option<NdTensor>,
}

/// Problem broadcast for the streaming path. Mirrors [`SetDictMsg`]:
/// the in-process transport ships `Shared` (one `Arc`d problem + warm
/// start for the whole grid), the socket transport flattens it to the
/// [`ProblemUpdate`] wire form and the receiving worker rebuilds a
/// local `CscProblem`. The geometry (X dims, D dims) must match the
/// resident problem exactly — the workers' windows were sized from it
/// and are *not* re-partitioned on a swap.
#[derive(Clone, Debug)]
pub enum SetProblemMsg {
    /// Same-process broadcast: one shared problem + optional warm start.
    Shared { problem: Arc<CscProblem>, z0: Option<Arc<NdTensor>> },
    /// Cross-process broadcast: rebuild locally from the wire tensors.
    Wire(ProblemUpdate),
}

/// Coordinator/pool -> worker commands, plus worker -> worker traffic.
#[derive(Clone, Debug)]
pub enum WorkerMsg {
    /// A neighbour changed a coordinate whose V-box reaches our window.
    Update(UpdateMsg),
    /// Begin a solve phase (warm-started from the resident Z window).
    Solve,
    /// End the current solve phase and report `SolveDone`.
    Stop,
    /// Compute local φ^w/ψ^w partials from the resident windows.
    ComputeStats,
    /// Swap the dictionary; re-bootstrap beta warm from the resident Z.
    SetDict(SetDictMsg),
    /// Swap observation + dictionary on an unchanged geometry; reset Z
    /// (optionally to a provided warm start) and re-bootstrap beta.
    SetProblem(SetProblemMsg),
    /// Re-enter the solve loop speculatively under the current
    /// dictionary (pipelined alternation: the coordinator overlaps the
    /// dictionary PGD with this resumed solve and lands `SetDict`
    /// mid-phase).
    ResumeSolve,
    /// Report the cell's activation values (final assembly only).
    Gather,
    /// Exit the worker thread.
    Shutdown,
}

/// Worker status transition, carrying message counters for the
/// Safra-style termination detection: global convergence holds when
/// every worker is idle and `sum(sent) == sum(received)` (no messages
/// in flight). Counters are cumulative over the pool's lifetime (see
/// the module docs for the between-phase rules).
#[derive(Clone, Debug, PartialEq)]
pub struct StatusMsg {
    pub from: usize,
    pub idle: bool,
    pub sent: u64,
    pub received: u64,
    /// Worker believes it converged locally (vs hit its update cap).
    pub converged: bool,
    /// Divergence guard tripped.
    pub diverged: bool,
}

/// End-of-solve-phase acknowledgement (the worker's last message of a
/// solve phase; the pool collects one per worker before moving on).
#[derive(Clone, Debug, PartialEq)]
pub struct SolveDoneMsg {
    pub from: usize,
    /// Snapshot of the cumulative worker counters.
    pub stats: WorkerStats,
}

/// Local φ/ψ partials over the worker's own cell `S_w` (eq. 17),
/// reduced by summation at the pool — full Z never leaves the workers.
#[derive(Clone, Debug)]
pub struct StatsMsg {
    pub from: usize,
    /// `phi^w : [K, K, (2L-1)..]`.
    pub phi: NdTensor,
    /// `psi^w : [K, P, L..]`.
    pub psi: NdTensor,
    /// `||Z||_1` restricted to the cell.
    pub z_l1: f64,
    /// Nonzeros in the cell.
    pub z_nnz: usize,
}

/// Final per-worker report for a `Gather`.
#[derive(Clone, Debug, PartialEq)]
pub struct DoneMsg {
    pub from: usize,
    /// Flat activation values over the worker's own cell `S_w`
    /// (row-major over `[K, cell extents..]`).
    pub z_cell: Vec<f64>,
    pub stats: WorkerStats,
}

/// Worker -> coordinator traffic.
#[derive(Clone, Debug)]
pub enum CoordMsg {
    Status(StatusMsg),
    SolveDone(SolveDoneMsg),
    Stats(StatsMsg),
    DictSet { from: usize },
    ProblemSet { from: usize },
    Done(DoneMsg),
}

/// Per-worker work counters (cumulative over the worker's lifetime).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerStats {
    /// Selection iterations (segments visited).
    pub iterations: u64,
    /// Accepted coordinate updates.
    pub updates: u64,
    /// Candidates rejected by the soft-lock.
    pub soft_locked: u64,
    /// Update messages sent to neighbours.
    pub msgs_sent: u64,
    /// Update messages received.
    pub msgs_received: u64,
    /// Full sweeps over the local segments.
    pub sweeps: u64,
    /// Clean-segment selection visits answered from the cached champion
    /// in O(1) (incremental selection; always 0 under
    /// `DICODILE_SELECT=rescan`).
    pub segments_skipped: u64,
    /// Dirty-segment rescans of the cached dz_opt (each costs K·|C_m|
    /// coordinate reads).
    pub segments_rescanned: u64,
    /// Coordinates whose cached dz_opt was computed by a full fill
    /// (one K·|window| fill at spawn and per `SetDict`; 0 under
    /// `DICODILE_SELECT=rescan`). Charged to `work` when it happens.
    pub dz_cache_filled: u64,
    /// Times the worker paused (went idle).
    pub pauses: u64,
    /// Abstract work units (coordinates scanned + beta entries touched):
    /// the per-worker clock of the simulated-time model used for the
    /// scaling figures (this testbed has a single physical core, so
    /// parallel wall-clock cannot be measured directly).
    pub work: u64,
    /// Solve phases run on this worker.
    pub solves: u64,
    /// Cold beta bootstraps from `Z = 0` (exactly one at spawn on the
    /// persistent path — never repeated between outer iterations).
    pub beta_cold_inits: u64,
    /// Warm beta bootstraps from a provided initial Z at spawn.
    pub beta_warm_inits: u64,
    /// Warm beta re-initializations from the resident Z after a
    /// `SetDict` broadcast.
    pub beta_warm_reinits: u64,
    /// `Gather` replies served (exactly one — the final assembly — per
    /// `learn_dictionary` run on the persistent path).
    pub gathers: u64,
    /// Accepted coordinate updates made *speculatively under a stale
    /// dictionary* — the updates a pipelined solve phase ran between a
    /// `ResumeSolve` and the mid-solve `SetDict` that retired the old
    /// dictionary. Always 0 under `Barrier` alternation.
    pub overlap_updates: u64,
}

impl WorkerStats {
    pub fn merge(&mut self, other: &WorkerStats) {
        self.iterations += other.iterations;
        self.updates += other.updates;
        self.soft_locked += other.soft_locked;
        self.msgs_sent += other.msgs_sent;
        self.msgs_received += other.msgs_received;
        self.sweeps += other.sweeps;
        self.segments_skipped += other.segments_skipped;
        self.segments_rescanned += other.segments_rescanned;
        self.dz_cache_filled += other.dz_cache_filled;
        self.pauses += other.pauses;
        self.work += other.work;
        self.solves += other.solves;
        self.beta_cold_inits += other.beta_cold_inits;
        self.beta_warm_inits += other.beta_warm_inits;
        self.beta_warm_reinits += other.beta_warm_reinits;
        self.gathers += other.gathers;
        self.overlap_updates += other.overlap_updates;
    }
}

// ---------------------------------------------------------------------------
// Wire codec
// ---------------------------------------------------------------------------

/// Strict-decode failure for a wire frame.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The payload ended in the middle of a field.
    Truncated,
    /// Unknown frame tag.
    BadTag(u8),
    /// A field held a non-canonical value (named for diagnostics).
    BadValue(&'static str),
    /// The payload had this many bytes left over after the last field.
    TrailingBytes(usize),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadTag(t) => write!(f, "unknown frame tag {t}"),
            WireError::BadValue(what) => write!(f, "bad wire value: {what}"),
            WireError::TrailingBytes(n) => write!(f, "{n} trailing bytes after frame"),
        }
    }
}

/// A decoded wire frame: everything that can arrive on a socket edge.
#[derive(Clone, Debug)]
pub enum WireFrame {
    /// Coordinator -> worker command (or a routed neighbour `Update`).
    Worker(WorkerMsg),
    /// Worker -> coordinator reply.
    Coord(CoordMsg),
    /// Worker -> worker notification, routed through the hub: "deliver
    /// this `Update` to worker `to`".
    Fwd { to: usize, msg: UpdateMsg },
    /// Problem + config handshake for a served worker
    /// (`dicodile worker --listen`).
    Bootstrap(Box<BootstrapMsg>),
}

/// Everything a freshly launched `dicodile worker --listen` process
/// needs to join a grid: its rank, the grid/solver configuration, and
/// the problem data (X, D, λ, optional warm-start Z). Sent once, as the
/// first frame on the connection.
#[derive(Clone, Debug)]
pub struct BootstrapMsg {
    pub rank: usize,
    pub n_workers: usize,
    /// `PartitionKind` code: 0 = Line, 1 = Grid.
    pub partition: u8,
    /// `Strategy` code: 0 = Greedy, 1 = Randomized, 2 = LocallyGreedy.
    pub strategy: u8,
    /// `SelectMode` code: 0 = Rescan, 1 = Incremental.
    pub select: u8,
    pub soft_lock: bool,
    pub tol: f64,
    pub max_updates: u64,
    pub divergence_guard: Option<f64>,
    pub seed: u64,
    pub timeout: f64,
    pub inbox_every: u64,
    pub x: NdTensor,
    pub d: NdTensor,
    pub lambda: f64,
    pub z0: Option<NdTensor>,
}

const TAG_UPDATE: u8 = 1;
const TAG_SOLVE: u8 = 2;
const TAG_STOP: u8 = 3;
const TAG_COMPUTE_STATS: u8 = 4;
const TAG_SET_DICT: u8 = 5;
const TAG_GATHER: u8 = 6;
const TAG_SHUTDOWN: u8 = 7;
const TAG_FWD: u8 = 8;
const TAG_STATUS: u8 = 9;
const TAG_SOLVE_DONE: u8 = 10;
const TAG_STATS: u8 = 11;
const TAG_DICT_SET: u8 = 12;
const TAG_DONE: u8 = 13;
const TAG_BOOTSTRAP: u8 = 14;
const TAG_SET_PROBLEM: u8 = 15;
const TAG_PROBLEM_SET: u8 = 16;
const TAG_RESUME_SOLVE: u8 = 17;

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

fn put_i64(out: &mut Vec<u8>, v: i64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

fn put_bool(out: &mut Vec<u8>, v: bool) {
    out.push(v as u8);
}

fn put_vec_i64(out: &mut Vec<u8>, v: &[i64]) {
    put_usize(out, v.len());
    for &x in v {
        put_i64(out, x);
    }
}

fn put_vec_f64(out: &mut Vec<u8>, v: &[f64]) {
    put_usize(out, v.len());
    for &x in v {
        put_f64(out, x);
    }
}

fn put_tensor(out: &mut Vec<u8>, t: &NdTensor) {
    put_usize(out, t.dims().len());
    for &d in t.dims() {
        put_usize(out, d);
    }
    put_vec_f64(out, t.data());
}

fn put_stats(out: &mut Vec<u8>, s: &WorkerStats) {
    for v in [
        s.iterations,
        s.updates,
        s.soft_locked,
        s.msgs_sent,
        s.msgs_received,
        s.sweeps,
        s.segments_skipped,
        s.segments_rescanned,
        s.dz_cache_filled,
        s.pauses,
        s.work,
        s.solves,
        s.beta_cold_inits,
        s.beta_warm_inits,
        s.beta_warm_reinits,
        s.gathers,
        s.overlap_updates,
    ] {
        put_u64(out, v);
    }
}

fn put_update(out: &mut Vec<u8>, m: &UpdateMsg) {
    put_usize(out, m.from);
    put_usize(out, m.k);
    put_vec_i64(out, &m.u);
    put_f64(out, m.dz);
}

fn put_dict_update(out: &mut Vec<u8>, du: &DictUpdate) {
    put_tensor(out, &du.d);
    put_f64(out, du.lambda);
    put_u64(out, du.fingerprint);
}

fn put_problem_update(out: &mut Vec<u8>, pu: &ProblemUpdate) {
    put_tensor(out, &pu.x);
    put_tensor(out, &pu.d);
    put_f64(out, pu.lambda);
    put_bool(out, pu.z0.is_some());
    if let Some(z0) = &pu.z0 {
        put_tensor(out, z0);
    }
}

/// Strict little-endian payload reader. Every getter fails with
/// `Truncated` past the end; `finish` rejects trailing bytes.
struct Wire<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Wire<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Wire { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8_(&mut self) -> Result<u8, WireError> {
        Ok(self.bytes(1)?[0])
    }

    fn u64_(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn usize_(&mut self) -> Result<usize, WireError> {
        usize::try_from(self.u64_()?).map_err(|_| WireError::BadValue("usize overflow"))
    }

    fn i64_(&mut self) -> Result<i64, WireError> {
        Ok(i64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f64_(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64_()?))
    }

    fn bool_(&mut self) -> Result<bool, WireError> {
        match self.u8_()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(WireError::BadValue("bool")),
        }
    }

    /// Guard a count field against absurd allocations: the elements
    /// that follow need at least `elem_size` bytes each, so a count
    /// larger than the remaining payload is always malformed.
    fn count(&mut self, elem_size: usize) -> Result<usize, WireError> {
        let n = self.usize_()?;
        if n.saturating_mul(elem_size) > self.buf.len() - self.pos {
            return Err(WireError::Truncated);
        }
        Ok(n)
    }

    fn vec_i64(&mut self) -> Result<Vec<i64>, WireError> {
        let n = self.count(8)?;
        (0..n).map(|_| self.i64_()).collect()
    }

    fn vec_f64(&mut self) -> Result<Vec<f64>, WireError> {
        let n = self.count(8)?;
        (0..n).map(|_| self.f64_()).collect()
    }

    fn tensor(&mut self) -> Result<NdTensor, WireError> {
        let ndim = self.count(8)?;
        let dims: Vec<usize> = (0..ndim).map(|_| self.usize_()).collect::<Result<_, _>>()?;
        let data = self.vec_f64()?;
        if data.len() != dims.iter().product::<usize>() {
            return Err(WireError::BadValue("tensor data length"));
        }
        Ok(NdTensor::from_vec(&dims, data))
    }

    fn stats(&mut self) -> Result<WorkerStats, WireError> {
        Ok(WorkerStats {
            iterations: self.u64_()?,
            updates: self.u64_()?,
            soft_locked: self.u64_()?,
            msgs_sent: self.u64_()?,
            msgs_received: self.u64_()?,
            sweeps: self.u64_()?,
            segments_skipped: self.u64_()?,
            segments_rescanned: self.u64_()?,
            dz_cache_filled: self.u64_()?,
            pauses: self.u64_()?,
            work: self.u64_()?,
            solves: self.u64_()?,
            beta_cold_inits: self.u64_()?,
            beta_warm_inits: self.u64_()?,
            beta_warm_reinits: self.u64_()?,
            gathers: self.u64_()?,
            overlap_updates: self.u64_()?,
        })
    }

    fn update(&mut self) -> Result<UpdateMsg, WireError> {
        Ok(UpdateMsg {
            from: self.usize_()?,
            k: self.usize_()?,
            u: self.vec_i64()?,
            dz: self.f64_()?,
        })
    }

    fn dict_update(&mut self) -> Result<DictUpdate, WireError> {
        Ok(DictUpdate {
            d: self.tensor()?,
            lambda: self.f64_()?,
            fingerprint: self.u64_()?,
        })
    }

    fn finish<T>(self, v: T) -> Result<T, WireError> {
        let left = self.buf.len() - self.pos;
        if left != 0 {
            return Err(WireError::TrailingBytes(left));
        }
        Ok(v)
    }
}

/// Encode a coordinator -> worker command as a frame payload. `SetDict`
/// is flattened to its [`DictUpdate`] wire form — the `Arc` never
/// crosses the seam.
pub fn encode_worker_frame(msg: &WorkerMsg) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        WorkerMsg::Update(u) => {
            out.push(TAG_UPDATE);
            put_update(&mut out, u);
        }
        WorkerMsg::Solve => out.push(TAG_SOLVE),
        WorkerMsg::Stop => out.push(TAG_STOP),
        WorkerMsg::ComputeStats => out.push(TAG_COMPUTE_STATS),
        WorkerMsg::SetDict(sd) => {
            out.push(TAG_SET_DICT);
            match sd {
                SetDictMsg::Shared(p) => put_dict_update(&mut out, &DictUpdate::from_problem(p)),
                SetDictMsg::Wire(du) => put_dict_update(&mut out, du),
            }
        }
        WorkerMsg::SetProblem(sp) => {
            out.push(TAG_SET_PROBLEM);
            match sp {
                SetProblemMsg::Shared { problem, z0 } => put_problem_update(
                    &mut out,
                    &ProblemUpdate {
                        x: (*problem.x).clone(),
                        d: problem.d.clone(),
                        lambda: problem.lambda,
                        z0: z0.as_ref().map(|z| (**z).clone()),
                    },
                ),
                SetProblemMsg::Wire(pu) => put_problem_update(&mut out, pu),
            }
        }
        WorkerMsg::ResumeSolve => out.push(TAG_RESUME_SOLVE),
        WorkerMsg::Gather => out.push(TAG_GATHER),
        WorkerMsg::Shutdown => out.push(TAG_SHUTDOWN),
    }
    out
}

/// Encode a worker -> coordinator reply as a frame payload.
pub fn encode_coord_frame(msg: &CoordMsg) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        CoordMsg::Status(s) => {
            out.push(TAG_STATUS);
            put_usize(&mut out, s.from);
            put_bool(&mut out, s.idle);
            put_u64(&mut out, s.sent);
            put_u64(&mut out, s.received);
            put_bool(&mut out, s.converged);
            put_bool(&mut out, s.diverged);
        }
        CoordMsg::SolveDone(d) => {
            out.push(TAG_SOLVE_DONE);
            put_usize(&mut out, d.from);
            put_stats(&mut out, &d.stats);
        }
        CoordMsg::Stats(s) => {
            out.push(TAG_STATS);
            put_usize(&mut out, s.from);
            put_tensor(&mut out, &s.phi);
            put_tensor(&mut out, &s.psi);
            put_f64(&mut out, s.z_l1);
            put_usize(&mut out, s.z_nnz);
        }
        CoordMsg::DictSet { from } => {
            out.push(TAG_DICT_SET);
            put_usize(&mut out, *from);
        }
        CoordMsg::ProblemSet { from } => {
            out.push(TAG_PROBLEM_SET);
            put_usize(&mut out, *from);
        }
        CoordMsg::Done(d) => {
            out.push(TAG_DONE);
            put_usize(&mut out, d.from);
            put_vec_f64(&mut out, &d.z_cell);
            put_stats(&mut out, &d.stats);
        }
    }
    out
}

/// Encode a routed neighbour notification ("hub: deliver to `to`").
pub fn encode_fwd_frame(to: usize, msg: &UpdateMsg) -> Vec<u8> {
    let mut out = vec![TAG_FWD];
    put_usize(&mut out, to);
    put_update(&mut out, msg);
    out
}

/// Encode the served-worker handshake.
pub fn encode_bootstrap_frame(b: &BootstrapMsg) -> Vec<u8> {
    let mut out = vec![TAG_BOOTSTRAP];
    put_usize(&mut out, b.rank);
    put_usize(&mut out, b.n_workers);
    out.push(b.partition);
    out.push(b.strategy);
    out.push(b.select);
    put_bool(&mut out, b.soft_lock);
    put_f64(&mut out, b.tol);
    put_u64(&mut out, b.max_updates);
    put_bool(&mut out, b.divergence_guard.is_some());
    if let Some(g) = b.divergence_guard {
        put_f64(&mut out, g);
    }
    put_u64(&mut out, b.seed);
    put_f64(&mut out, b.timeout);
    put_u64(&mut out, b.inbox_every);
    put_tensor(&mut out, &b.x);
    put_tensor(&mut out, &b.d);
    put_f64(&mut out, b.lambda);
    put_bool(&mut out, b.z0.is_some());
    if let Some(z0) = &b.z0 {
        put_tensor(&mut out, z0);
    }
    out
}

/// Strictly decode one frame payload. Rejects unknown tags, truncated
/// fields, non-canonical values and trailing bytes.
pub fn decode_frame(payload: &[u8]) -> Result<WireFrame, WireError> {
    let mut w = Wire::new(payload);
    let tag = w.u8_()?;
    match tag {
        TAG_UPDATE => {
            let u = w.update()?;
            w.finish(WireFrame::Worker(WorkerMsg::Update(u)))
        }
        TAG_SOLVE => w.finish(WireFrame::Worker(WorkerMsg::Solve)),
        TAG_STOP => w.finish(WireFrame::Worker(WorkerMsg::Stop)),
        TAG_COMPUTE_STATS => w.finish(WireFrame::Worker(WorkerMsg::ComputeStats)),
        TAG_SET_DICT => {
            let du = w.dict_update()?;
            w.finish(WireFrame::Worker(WorkerMsg::SetDict(SetDictMsg::Wire(du))))
        }
        TAG_SET_PROBLEM => {
            let x = w.tensor()?;
            let d = w.tensor()?;
            let lambda = w.f64_()?;
            let z0 = if w.bool_()? { Some(w.tensor()?) } else { None };
            w.finish(WireFrame::Worker(WorkerMsg::SetProblem(SetProblemMsg::Wire(
                ProblemUpdate { x, d, lambda, z0 },
            ))))
        }
        TAG_RESUME_SOLVE => w.finish(WireFrame::Worker(WorkerMsg::ResumeSolve)),
        TAG_GATHER => w.finish(WireFrame::Worker(WorkerMsg::Gather)),
        TAG_SHUTDOWN => w.finish(WireFrame::Worker(WorkerMsg::Shutdown)),
        TAG_FWD => {
            let to = w.usize_()?;
            let msg = w.update()?;
            w.finish(WireFrame::Fwd { to, msg })
        }
        TAG_STATUS => {
            let s = StatusMsg {
                from: w.usize_()?,
                idle: w.bool_()?,
                sent: w.u64_()?,
                received: w.u64_()?,
                converged: w.bool_()?,
                diverged: w.bool_()?,
            };
            w.finish(WireFrame::Coord(CoordMsg::Status(s)))
        }
        TAG_SOLVE_DONE => {
            let d = SolveDoneMsg { from: w.usize_()?, stats: w.stats()? };
            w.finish(WireFrame::Coord(CoordMsg::SolveDone(d)))
        }
        TAG_STATS => {
            let s = StatsMsg {
                from: w.usize_()?,
                phi: w.tensor()?,
                psi: w.tensor()?,
                z_l1: w.f64_()?,
                z_nnz: w.usize_()?,
            };
            w.finish(WireFrame::Coord(CoordMsg::Stats(s)))
        }
        TAG_DICT_SET => {
            let from = w.usize_()?;
            w.finish(WireFrame::Coord(CoordMsg::DictSet { from }))
        }
        TAG_PROBLEM_SET => {
            let from = w.usize_()?;
            w.finish(WireFrame::Coord(CoordMsg::ProblemSet { from }))
        }
        TAG_DONE => {
            let d = DoneMsg { from: w.usize_()?, z_cell: w.vec_f64()?, stats: w.stats()? };
            w.finish(WireFrame::Coord(CoordMsg::Done(d)))
        }
        TAG_BOOTSTRAP => {
            let rank = w.usize_()?;
            let n_workers = w.usize_()?;
            let partition = w.u8_()?;
            let strategy = w.u8_()?;
            let select = w.u8_()?;
            let soft_lock = w.bool_()?;
            let tol = w.f64_()?;
            let max_updates = w.u64_()?;
            let divergence_guard = if w.bool_()? { Some(w.f64_()?) } else { None };
            let seed = w.u64_()?;
            let timeout = w.f64_()?;
            let inbox_every = w.u64_()?;
            let x = w.tensor()?;
            let d = w.tensor()?;
            let lambda = w.f64_()?;
            let z0 = if w.bool_()? { Some(w.tensor()?) } else { None };
            w.finish(WireFrame::Bootstrap(Box::new(BootstrapMsg {
                rank,
                n_workers,
                partition,
                strategy,
                select,
                soft_lock,
                tol,
                max_updates,
                divergence_guard,
                seed,
                timeout,
                inbox_every,
                x,
                d,
                lambda,
                z0,
            })))
        }
        other => Err(WireError::BadTag(other)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_merge() {
        let mut a = WorkerStats {
            updates: 3,
            msgs_sent: 1,
            segments_skipped: 10,
            ..Default::default()
        };
        let b = WorkerStats {
            updates: 4,
            soft_locked: 2,
            segments_skipped: 5,
            segments_rescanned: 7,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.updates, 7);
        assert_eq!(a.soft_locked, 2);
        assert_eq!(a.msgs_sent, 1);
        assert_eq!(a.segments_skipped, 15);
        assert_eq!(a.segments_rescanned, 7);
    }

    #[test]
    fn stats_merge_phase_counters() {
        let mut a = WorkerStats { solves: 2, beta_cold_inits: 1, gathers: 1, ..Default::default() };
        let b = WorkerStats { solves: 3, beta_warm_reinits: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.solves, 5);
        assert_eq!(a.beta_cold_inits, 1);
        assert_eq!(a.beta_warm_reinits, 2);
        assert_eq!(a.gathers, 1);
    }

    #[test]
    fn geometry_fingerprint_separates_shapes() {
        let a = DictUpdate::geometry_fingerprint(&[1, 100], &[3, 1, 8]);
        let b = DictUpdate::geometry_fingerprint(&[1, 100], &[4, 1, 8]);
        let c = DictUpdate::geometry_fingerprint(&[1, 101], &[3, 1, 8]);
        assert_ne!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn update_frame_round_trips_exactly() {
        let m = UpdateMsg { from: 3, k: 7, u: vec![-2, 41], dz: -0.125 };
        let frame = encode_worker_frame(&WorkerMsg::Update(m.clone()));
        match decode_frame(&frame).unwrap() {
            WireFrame::Worker(WorkerMsg::Update(got)) => assert_eq!(got, m),
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn resume_solve_frame_round_trips_exactly() {
        let frame = encode_worker_frame(&WorkerMsg::ResumeSolve);
        assert_eq!(frame.len(), 1, "ResumeSolve is a tag-only frame");
        match decode_frame(&frame).unwrap() {
            WireFrame::Worker(WorkerMsg::ResumeSolve) => {}
            other => panic!("wrong frame: {other:?}"),
        }
        // Strictness holds for the new tag too.
        let mut padded = frame.clone();
        padded.push(0);
        assert!(matches!(decode_frame(&padded), Err(WireError::TrailingBytes(1))));
    }

    #[test]
    fn nan_and_negative_zero_survive_the_wire() {
        let m = UpdateMsg { from: 0, k: 0, u: vec![0], dz: f64::NAN };
        let frame = encode_worker_frame(&WorkerMsg::Update(m));
        match decode_frame(&frame).unwrap() {
            WireFrame::Worker(WorkerMsg::Update(got)) => {
                assert_eq!(got.dz.to_bits(), f64::NAN.to_bits())
            }
            other => panic!("wrong frame: {other:?}"),
        }
        let z = UpdateMsg { from: 0, k: 0, u: vec![0], dz: -0.0 };
        let frame = encode_worker_frame(&WorkerMsg::Update(z));
        match decode_frame(&frame).unwrap() {
            WireFrame::Worker(WorkerMsg::Update(got)) => {
                assert_eq!(got.dz.to_bits(), (-0.0f64).to_bits())
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn set_problem_frame_round_trips_exactly() {
        for z0 in [None, Some(NdTensor::from_vec(&[2, 4], vec![0.0, 1.5, 0.0, -2.0, 0.0, 0.0, 3.0, 0.0]))] {
            let pu = ProblemUpdate {
                x: NdTensor::from_vec(&[1, 7], (0..7).map(|i| i as f64 * 0.5).collect()),
                d: NdTensor::from_vec(&[2, 1, 4], (0..8).map(|i| -(i as f64)).collect()),
                lambda: 0.125,
                z0,
            };
            let frame =
                encode_worker_frame(&WorkerMsg::SetProblem(SetProblemMsg::Wire(pu.clone())));
            match decode_frame(&frame).unwrap() {
                WireFrame::Worker(WorkerMsg::SetProblem(SetProblemMsg::Wire(got))) => {
                    assert_eq!(got.x.data(), pu.x.data());
                    assert_eq!(got.x.dims(), pu.x.dims());
                    assert_eq!(got.d.data(), pu.d.data());
                    assert_eq!(got.lambda, pu.lambda);
                    match (&got.z0, &pu.z0) {
                        (None, None) => {}
                        (Some(a), Some(b)) => {
                            assert_eq!(a.dims(), b.dims());
                            assert_eq!(a.data(), b.data());
                        }
                        other => panic!("z0 mismatch: {other:?}"),
                    }
                }
                other => panic!("wrong frame: {other:?}"),
            }
        }
    }

    #[test]
    fn shared_set_problem_flattens_to_wire() {
        // The channel->socket seam encodes a Shared broadcast down to
        // its wire tensors; the decoded form must carry the same data.
        let x = NdTensor::from_vec(&[1, 10], (0..10).map(|i| i as f64).collect());
        let d = NdTensor::from_vec(&[1, 1, 3], vec![1.0, -1.0, 0.5]);
        let p = Arc::new(CscProblem::new(x.clone(), d.clone(), 0.25));
        let z0 = Arc::new(NdTensor::from_vec(&[1, 8], vec![0.0; 8]));
        let frame = encode_worker_frame(&WorkerMsg::SetProblem(SetProblemMsg::Shared {
            problem: p,
            z0: Some(z0),
        }));
        match decode_frame(&frame).unwrap() {
            WireFrame::Worker(WorkerMsg::SetProblem(SetProblemMsg::Wire(got))) => {
                assert_eq!(got.x.data(), x.data());
                assert_eq!(got.d.data(), d.data());
                assert_eq!(got.lambda, 0.25);
                assert_eq!(got.z0.unwrap().dims(), &[1, 8]);
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn problem_set_reply_round_trips() {
        let frame = encode_coord_frame(&CoordMsg::ProblemSet { from: 5 });
        match decode_frame(&frame).unwrap() {
            WireFrame::Coord(CoordMsg::ProblemSet { from }) => assert_eq!(from, 5),
            other => panic!("wrong frame: {other:?}"),
        }
        // Truncated reply payloads are rejected.
        assert!(matches!(decode_frame(&frame[..frame.len() - 1]), Err(WireError::Truncated)));
    }

    #[test]
    fn malformed_frames_are_rejected() {
        // Unknown tag.
        assert!(matches!(decode_frame(&[200]), Err(WireError::BadTag(200))));
        // Empty payload.
        assert!(matches!(decode_frame(&[]), Err(WireError::Truncated)));
        // Truncated field.
        let full = encode_worker_frame(&WorkerMsg::Update(UpdateMsg {
            from: 1,
            k: 2,
            u: vec![3],
            dz: 4.0,
        }));
        assert!(matches!(decode_frame(&full[..full.len() - 1]), Err(WireError::Truncated)));
        // Trailing bytes.
        let mut padded = full.clone();
        padded.push(0);
        assert!(matches!(decode_frame(&padded), Err(WireError::TrailingBytes(1))));
        // Absurd element count (count field larger than the payload).
        let mut bad = vec![TAG_DONE];
        put_usize(&mut bad, 0); // from
        put_u64(&mut bad, u64::MAX); // z_cell count
        assert!(matches!(decode_frame(&bad), Err(WireError::Truncated)));
    }
}
