//! Messages exchanged by the distributed runtime.
//!
//! Workers talk to their grid neighbours (coordinate-update
//! notifications, the only hot-path traffic) and to the coordinator.
//! There is no central data server: the coordinator never sees beta or
//! Z until an explicit `Gather`, mirroring the paper's decentralized
//! design (§4.2) — between CDL alternations only status transitions,
//! phase commands and the (signal-size-independent) φ/ψ partials flow.
//!
//! ## Phase protocol (persistent pool)
//!
//! The pool drives resident workers through phases:
//!
//! | command        | worker reply           | effect                              |
//! |----------------|------------------------|-------------------------------------|
//! | `Solve`        | `Status`… `SolveDone`  | run DiCoDiLe-Z from the resident Z  |
//! | `Stop`         | (ends the solve phase) | sent by the pool on convergence     |
//! | `ComputeStats` | `Stats`                | local φ^w/ψ^w partials (eq. 17)     |
//! | `SetDict`      | `DictSet`              | swap D, warm beta re-init from Z    |
//! | `Gather`       | `Done`                 | report the cell's activation values |
//! | `Shutdown`     | (thread exits)         |                                     |
//!
//! Counter rules between phases: the Safra counters (`sent` /
//! `received`) are *cumulative over the pool's lifetime* — a
//! notification that is still queued when a solve phase ends is applied
//! (and counted received) while the worker idles between phases, so the
//! global balance `sum(sent) == sum(received)` always settles before
//! the next solve begins and the termination detection never sees a
//! phantom in-flight message. Per-solve state (update cap, divergence
//! flag, sweep position, deadline) resets at every `Solve`.

use std::sync::Arc;

use crate::csc::problem::CscProblem;
use crate::tensor::NdTensor;

/// A coordinate update notification `(k0, u0, dZ)` (§4.1, Fig. 2).
#[derive(Clone, Debug, PartialEq)]
pub struct UpdateMsg {
    pub from: usize,
    pub k: usize,
    pub u: Vec<i64>,
    pub dz: f64,
}

/// Dictionary broadcast: the rebuilt problem (same shared X, new D and
/// derived quantities). All workers receive clones of one `Arc`, so the
/// new engine's spectra cache is shared — the spectra are regenerated
/// once per broadcast, by whichever worker bootstraps first, not once
/// per worker.
#[derive(Clone, Debug)]
pub struct SetDictMsg {
    pub problem: Arc<CscProblem>,
}

/// Coordinator/pool -> worker commands, plus worker -> worker traffic.
#[derive(Clone, Debug)]
pub enum WorkerMsg {
    /// A neighbour changed a coordinate whose V-box reaches our window.
    Update(UpdateMsg),
    /// Begin a solve phase (warm-started from the resident Z window).
    Solve,
    /// End the current solve phase and report `SolveDone`.
    Stop,
    /// Compute local φ^w/ψ^w partials from the resident windows.
    ComputeStats,
    /// Swap the dictionary; re-bootstrap beta warm from the resident Z.
    SetDict(SetDictMsg),
    /// Report the cell's activation values (final assembly only).
    Gather,
    /// Exit the worker thread.
    Shutdown,
}

/// Worker status transition, carrying message counters for the
/// Safra-style termination detection: global convergence holds when
/// every worker is idle and `sum(sent) == sum(received)` (no messages
/// in flight). Counters are cumulative over the pool's lifetime (see
/// the module docs for the between-phase rules).
#[derive(Clone, Debug)]
pub struct StatusMsg {
    pub from: usize,
    pub idle: bool,
    pub sent: u64,
    pub received: u64,
    /// Worker believes it converged locally (vs hit its update cap).
    pub converged: bool,
    /// Divergence guard tripped.
    pub diverged: bool,
}

/// End-of-solve-phase acknowledgement (the worker's last message of a
/// solve phase; the pool collects one per worker before moving on).
#[derive(Clone, Debug)]
pub struct SolveDoneMsg {
    pub from: usize,
    /// Snapshot of the cumulative worker counters.
    pub stats: WorkerStats,
}

/// Local φ/ψ partials over the worker's own cell `S_w` (eq. 17),
/// reduced by summation at the pool — full Z never leaves the workers.
#[derive(Clone, Debug)]
pub struct StatsMsg {
    pub from: usize,
    /// `phi^w : [K, K, (2L-1)..]`.
    pub phi: NdTensor,
    /// `psi^w : [K, P, L..]`.
    pub psi: NdTensor,
    /// `||Z||_1` restricted to the cell.
    pub z_l1: f64,
    /// Nonzeros in the cell.
    pub z_nnz: usize,
}

/// Final per-worker report for a `Gather`.
#[derive(Clone, Debug)]
pub struct DoneMsg {
    pub from: usize,
    /// Flat activation values over the worker's own cell `S_w`
    /// (row-major over `[K, cell extents..]`).
    pub z_cell: Vec<f64>,
    pub stats: WorkerStats,
}

/// Worker -> coordinator traffic.
#[derive(Clone, Debug)]
pub enum CoordMsg {
    Status(StatusMsg),
    SolveDone(SolveDoneMsg),
    Stats(StatsMsg),
    DictSet { from: usize },
    Done(DoneMsg),
}

/// Per-worker work counters (cumulative over the worker's lifetime).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    /// Selection iterations (segments visited).
    pub iterations: u64,
    /// Accepted coordinate updates.
    pub updates: u64,
    /// Candidates rejected by the soft-lock.
    pub soft_locked: u64,
    /// Update messages sent to neighbours.
    pub msgs_sent: u64,
    /// Update messages received.
    pub msgs_received: u64,
    /// Full sweeps over the local segments.
    pub sweeps: u64,
    /// Clean-segment selection visits answered from the cached champion
    /// in O(1) (incremental selection; always 0 under
    /// `DICODILE_SELECT=rescan`).
    pub segments_skipped: u64,
    /// Dirty-segment rescans of the cached dz_opt (each costs K·|C_m|
    /// coordinate reads).
    pub segments_rescanned: u64,
    /// Coordinates whose cached dz_opt was computed by a full fill
    /// (one K·|window| fill at spawn and per `SetDict`; 0 under
    /// `DICODILE_SELECT=rescan`). Charged to `work` when it happens.
    pub dz_cache_filled: u64,
    /// Times the worker paused (went idle).
    pub pauses: u64,
    /// Abstract work units (coordinates scanned + beta entries touched):
    /// the per-worker clock of the simulated-time model used for the
    /// scaling figures (this testbed has a single physical core, so
    /// parallel wall-clock cannot be measured directly).
    pub work: u64,
    /// Solve phases run on this worker.
    pub solves: u64,
    /// Cold beta bootstraps from `Z = 0` (exactly one at spawn on the
    /// persistent path — never repeated between outer iterations).
    pub beta_cold_inits: u64,
    /// Warm beta bootstraps from a provided initial Z at spawn.
    pub beta_warm_inits: u64,
    /// Warm beta re-initializations from the resident Z after a
    /// `SetDict` broadcast.
    pub beta_warm_reinits: u64,
    /// `Gather` replies served (exactly one — the final assembly — per
    /// `learn_dictionary` run on the persistent path).
    pub gathers: u64,
}

impl WorkerStats {
    pub fn merge(&mut self, other: &WorkerStats) {
        self.iterations += other.iterations;
        self.updates += other.updates;
        self.soft_locked += other.soft_locked;
        self.msgs_sent += other.msgs_sent;
        self.msgs_received += other.msgs_received;
        self.sweeps += other.sweeps;
        self.segments_skipped += other.segments_skipped;
        self.segments_rescanned += other.segments_rescanned;
        self.dz_cache_filled += other.dz_cache_filled;
        self.pauses += other.pauses;
        self.work += other.work;
        self.solves += other.solves;
        self.beta_cold_inits += other.beta_cold_inits;
        self.beta_warm_inits += other.beta_warm_inits;
        self.beta_warm_reinits += other.beta_warm_reinits;
        self.gathers += other.gathers;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_merge() {
        let mut a = WorkerStats {
            updates: 3,
            msgs_sent: 1,
            segments_skipped: 10,
            ..Default::default()
        };
        let b = WorkerStats {
            updates: 4,
            soft_locked: 2,
            segments_skipped: 5,
            segments_rescanned: 7,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.updates, 7);
        assert_eq!(a.soft_locked, 2);
        assert_eq!(a.msgs_sent, 1);
        assert_eq!(a.segments_skipped, 15);
        assert_eq!(a.segments_rescanned, 7);
    }

    #[test]
    fn stats_merge_phase_counters() {
        let mut a = WorkerStats { solves: 2, beta_cold_inits: 1, gathers: 1, ..Default::default() };
        let b = WorkerStats { solves: 3, beta_warm_reinits: 2, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.solves, 5);
        assert_eq!(a.beta_cold_inits, 1);
        assert_eq!(a.beta_warm_reinits, 2);
        assert_eq!(a.gathers, 1);
    }
}
