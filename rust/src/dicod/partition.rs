//! Worker-grid partitioning of the activation domain (§4.1).
//!
//! The activation domain `Omega' = prod_i [0, T'_i)` is split into `W`
//! contiguous sub-domains `S_w`: either along the first dimension only
//! (the DICOD baseline's *line* partition) or on a d-dimensional *grid*
//! (DiCoDiLe-Z). Each worker also maintains a halo of width `L_i - 1`
//! around its cell — the `Theta`-extension `E_L(S_w)` on which beta and
//! Z are kept up to date via neighbour notifications, and which the
//! soft-lock rule (eq. 14) inspects.
//!
//! Neighbour topology is expressed as *transport-addressable worker
//! ids* ([`NeighborLink`]): the grid says *which rank* an update must
//! reach, and the transport seam ([`crate::dicod::transport`]) decides
//! how the message gets there — an in-process channel today, a routed
//! socket frame tomorrow. No channel handles live in the topology.

use crate::tensor::shape::Rect;

/// One entry of a worker's neighbour list: the destination worker id
/// (the address a `WorkerEndpoint::send_update` routes on) and that
/// worker's extended window `E_L(S_{w'})`, against which the sender
/// tests `V(u0)` overlap to decide whether a notification is due.
#[derive(Clone, Debug)]
pub struct NeighborLink {
    pub rank: usize,
    pub ext_window: Rect,
}

/// How the domain is split across workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionKind {
    /// Split along the first spatial dimension only (as in DICOD).
    Line,
    /// Split along all spatial dimensions on a near-square grid.
    Grid,
}

impl std::str::FromStr for PartitionKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "line" => Ok(PartitionKind::Line),
            "grid" => Ok(PartitionKind::Grid),
            other => Err(format!("unknown partition {other:?} (line|grid)")),
        }
    }
}

/// The worker grid: per-dimension worker counts and cell boundaries.
#[derive(Clone, Debug)]
pub struct WorkerGrid {
    /// Activation spatial dims `T'..`.
    pub zsp: Vec<usize>,
    /// Atom spatial dims `L..` (halo width is `L_i - 1`).
    pub ldims: Vec<usize>,
    /// Workers per dimension `W_i` (`prod = W`).
    pub wdims: Vec<usize>,
    /// Per-dimension cell boundaries, `wdims[i] + 1` entries each.
    pub bounds: Vec<Vec<i64>>,
}

impl WorkerGrid {
    /// Build a grid of `w` workers over `zsp` with the given partition
    /// kind. For `Grid`, `w` is factorized so that per-dimension cell
    /// extents stay as balanced as possible (cells roughly similar in
    /// units of atoms).
    pub fn new(zsp: &[usize], ldims: &[usize], w: usize, kind: PartitionKind) -> Self {
        assert!(w >= 1);
        assert_eq!(zsp.len(), ldims.len());
        let wdims = match kind {
            PartitionKind::Line => {
                let mut v = vec![1; zsp.len()];
                v[0] = w;
                v
            }
            PartitionKind::Grid => factorize_balanced(w, zsp),
        };
        for (i, (&wi, &ti)) in wdims.iter().zip(zsp).enumerate() {
            assert!(
                wi <= ti,
                "more workers than coordinates along dim {i}: {wi} > {ti}"
            );
        }
        let bounds = wdims
            .iter()
            .zip(zsp)
            .map(|(&wi, &ti)| {
                (0..=wi)
                    .map(|j| ((j as f64) * (ti as f64) / (wi as f64)).round() as i64)
                    .collect()
            })
            .collect();
        WorkerGrid { zsp: zsp.to_vec(), ldims: ldims.to_vec(), wdims, bounds }
    }

    /// Total number of workers.
    pub fn n_workers(&self) -> usize {
        self.wdims.iter().product()
    }

    /// Grid index of worker `w` (row-major over `wdims`).
    pub fn grid_index(&self, w: usize) -> Vec<usize> {
        let mut rem = w;
        let d = self.wdims.len();
        let mut idx = vec![0usize; d];
        for i in (0..d).rev() {
            idx[i] = rem % self.wdims[i];
            rem /= self.wdims[i];
        }
        idx
    }

    /// Worker rank from grid index.
    pub fn rank_of(&self, idx: &[usize]) -> usize {
        let mut r = 0;
        for (x, n) in idx.iter().zip(&self.wdims) {
            r = r * n + x;
        }
        r
    }

    /// The sub-domain `S_w` (global coords).
    pub fn cell(&self, w: usize) -> Rect {
        let idx = self.grid_index(w);
        let lo: Vec<i64> = idx.iter().zip(&self.bounds).map(|(&i, b)| b[i]).collect();
        let hi: Vec<i64> = idx.iter().zip(&self.bounds).map(|(&i, b)| b[i + 1]).collect();
        Rect::new(lo, hi)
    }

    /// `S_w` extended by the halo (`L_i - 1` per side), clipped to the
    /// domain: the window on which worker `w` maintains beta and Z.
    pub fn extended_cell(&self, w: usize) -> Rect {
        let margins: Vec<usize> = self.ldims.iter().map(|&l| l - 1).collect();
        self.cell(w).dilate(&margins).intersect(&Rect::full(&self.zsp))
    }

    /// Worker owning a global coordinate.
    pub fn owner_of(&self, u: &[i64]) -> usize {
        let idx: Vec<usize> = u
            .iter()
            .zip(&self.bounds)
            .map(|(x, b)| {
                // last j with b[j] <= x
                let mut lo = 0usize;
                let mut hi = b.len() - 1;
                while lo + 1 < hi {
                    let mid = (lo + hi) / 2;
                    if b[mid] <= *x {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                lo
            })
            .collect();
        self.rank_of(&idx)
    }

    /// Ranks of all workers whose *extended* window this worker's
    /// updates can reach: any `w'` with `cell(w')` within `2(L-1)` of
    /// `cell(w)` (the paper's `B_2L` notification footprint). On a
    /// regular grid this is the Moore neighbourhood as long as cells
    /// are at least `L - 1` wide; smaller cells reach further, which
    /// this computation handles by widening the search radius.
    pub fn neighbors(&self, w: usize) -> Vec<usize> {
        let me = self.cell(w);
        let margins: Vec<usize> = self.ldims.iter().map(|&l| 2 * (l - 1)).collect();
        let reach = me.dilate(&margins);
        (0..self.n_workers())
            .filter(|&w2| w2 != w && reach.overlaps(&self.cell(w2)))
            .collect()
    }

    /// The neighbour topology as transport-addressable links: for each
    /// rank in [`WorkerGrid::neighbors`], the destination worker id
    /// (what a [`crate::dicod::transport::WorkerEndpoint`] routes on —
    /// never a raw channel handle) paired with that worker's extended
    /// window, which is the overlap test deciding whether a given
    /// update must be notified to it.
    pub fn neighbor_links(&self, w: usize) -> Vec<NeighborLink> {
        self.neighbors(w)
            .into_iter()
            .map(|rank| NeighborLink { rank, ext_window: self.extended_cell(rank) })
            .collect()
    }

    /// The update neighbourhood `V(u0) = prod [u0 - L + 1, u0 + L)`.
    pub fn v_box(&self, u0: &[i64]) -> Rect {
        Rect::new(
            u0.iter().zip(&self.ldims).map(|(x, &l)| x - l as i64 + 1).collect(),
            u0.iter().zip(&self.ldims).map(|(x, &l)| x + l as i64).collect(),
        )
    }

    /// Is `u` in the inner border `B_L(S_w)` (within `L_i - 1` of the
    /// cell boundary, on the inside — updates here can interfere with a
    /// neighbour)? Domain edges (where there is no neighbour) do not
    /// count as borders.
    pub fn in_soft_border(&self, w: usize, u: &[i64]) -> bool {
        let cell = self.cell(w);
        for i in 0..u.len() {
            let l = self.ldims[i] as i64;
            if cell.lo[i] > 0 && u[i] < cell.lo[i] + l - 1 {
                return true;
            }
            if cell.hi[i] < self.zsp[i] as i64 && u[i] > cell.hi[i] - l {
                return true;
            }
        }
        false
    }
}

/// Factorize `w` into `dims.len()` factors proportional to `dims`
/// (largest factors on the largest extents), so worker cells stay
/// roughly cubic.
fn factorize_balanced(w: usize, dims: &[usize]) -> Vec<usize> {
    let d = dims.len();
    if d == 1 {
        return vec![w];
    }
    // Enumerate factorizations recursively, keep the one minimizing the
    // max cell aspect ratio (cell extent per unit).
    fn rec(
        rem: usize,
        dim_i: usize,
        dims: &[usize],
        cur: &mut Vec<usize>,
        best: &mut (f64, Vec<usize>),
    ) {
        if dim_i == dims.len() - 1 {
            cur.push(rem);
            // score: max over dims of cell extent / min cell extent
            let exts: Vec<f64> = dims
                .iter()
                .zip(cur.iter())
                .map(|(&t, &wi)| t as f64 / wi as f64)
                .collect();
            let valid = dims.iter().zip(cur.iter()).all(|(&t, &wi)| wi <= t);
            if valid {
                let mx = exts.iter().cloned().fold(f64::MIN, f64::max);
                let mn = exts.iter().cloned().fold(f64::MAX, f64::min);
                let score = mx / mn;
                if score < best.0 {
                    *best = (score, cur.clone());
                }
            }
            cur.pop();
            return;
        }
        let mut f = 1;
        while f * f <= rem || f <= rem {
            if rem % f == 0 {
                cur.push(f);
                rec(rem / f, dim_i + 1, dims, cur, best);
                cur.pop();
            }
            f += 1;
            if f > rem {
                break;
            }
        }
    }
    let mut best = (f64::MAX, vec![1; d]);
    let mut cur = Vec::new();
    rec(w, 0, dims, &mut cur, &mut best);
    assert!(
        best.0 < f64::MAX,
        "no valid factorization of {w} workers over dims {dims:?}"
    );
    best.1
}

/// Decompose `ext \ core` into disjoint boxes (at most `2 d`).
/// Used by the soft-lock check: the extension `E_L(S_w)` is exactly
/// `extended_cell \ cell`.
pub fn box_difference(ext: &Rect, core: &Rect) -> Vec<Rect> {
    let mut out = Vec::new();
    let mut inner = ext.clone();
    for i in 0..ext.ndim() {
        // slab below core along dim i
        if inner.lo[i] < core.lo[i] {
            let mut slab = inner.clone();
            slab.hi[i] = core.lo[i].min(inner.hi[i]);
            if !slab.is_empty() {
                out.push(slab);
            }
        }
        // slab above core along dim i
        if inner.hi[i] > core.hi[i] {
            let mut slab = inner.clone();
            slab.lo[i] = core.hi[i].max(inner.lo[i]);
            if !slab.is_empty() {
                out.push(slab);
            }
        }
        inner.lo[i] = inner.lo[i].max(core.lo[i]);
        inner.hi[i] = inner.hi[i].min(core.hi[i]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_partition_splits_first_dim() {
        let g = WorkerGrid::new(&[100, 50], &[8, 8], 4, PartitionKind::Line);
        assert_eq!(g.wdims, vec![4, 1]);
        assert_eq!(g.cell(0), Rect::new(vec![0, 0], vec![25, 50]));
        assert_eq!(g.cell(3), Rect::new(vec![75, 0], vec![100, 50]));
    }

    #[test]
    fn grid_partition_balanced() {
        let g = WorkerGrid::new(&[100, 100], &[8, 8], 4, PartitionKind::Grid);
        assert_eq!(g.wdims, vec![2, 2]);
        let g9 = WorkerGrid::new(&[90, 90], &[8, 8], 9, PartitionKind::Grid);
        assert_eq!(g9.wdims, vec![3, 3]);
    }

    #[test]
    fn grid_partition_rect_domain() {
        // 200 x 50: 8 workers should go 4x2 not 2x4.
        let g = WorkerGrid::new(&[200, 50], &[8, 8], 8, PartitionKind::Grid);
        assert_eq!(g.wdims, vec![4, 2]);
    }

    #[test]
    fn cells_tile_domain() {
        let g = WorkerGrid::new(&[37, 23], &[4, 4], 6, PartitionKind::Grid);
        let mut count = 0usize;
        for w in 0..g.n_workers() {
            count += g.cell(w).size();
        }
        assert_eq!(count, 37 * 23);
        // disjoint: owner_of is consistent
        for w in 0..g.n_workers() {
            for pt in g.cell(w).iter() {
                assert_eq!(g.owner_of(&pt), w);
            }
        }
    }

    #[test]
    fn extended_cell_clips_to_domain() {
        let g = WorkerGrid::new(&[40], &[5], 4, PartitionKind::Line);
        assert_eq!(g.extended_cell(0), Rect::new(vec![0], vec![14]));
        assert_eq!(g.extended_cell(1), Rect::new(vec![6], vec![24]));
    }

    #[test]
    fn neighbors_moore_2d() {
        let g = WorkerGrid::new(&[60, 60], &[4, 4], 9, PartitionKind::Grid);
        // center worker (1,1) = rank 4 has 8 neighbours
        let mut n = g.neighbors(4);
        n.sort_unstable();
        assert_eq!(n, vec![0, 1, 2, 3, 5, 6, 7, 8]);
        // corner worker 0 has 3
        assert_eq!(g.neighbors(0).len(), 3);
    }

    #[test]
    fn neighbor_links_carry_ext_windows() {
        let g = WorkerGrid::new(&[40], &[5], 4, PartitionKind::Line);
        let links = g.neighbor_links(1);
        let ranks: Vec<usize> = links.iter().map(|l| l.rank).collect();
        assert_eq!(ranks, g.neighbors(1));
        for l in &links {
            assert_eq!(l.ext_window, g.extended_cell(l.rank));
        }
    }

    #[test]
    fn soft_border_detection() {
        let g = WorkerGrid::new(&[40], &[5], 2, PartitionKind::Line);
        // worker 0: cell [0,20); interior boundary at 20; border = [16,20)
        assert!(!g.in_soft_border(0, &[0])); // domain edge, no neighbour
        assert!(!g.in_soft_border(0, &[15]));
        assert!(g.in_soft_border(0, &[16]));
        assert!(g.in_soft_border(0, &[19]));
        // worker 1: cell [20,40); border = [20,24)
        assert!(g.in_soft_border(1, &[20]));
        assert!(g.in_soft_border(1, &[23]));
        assert!(!g.in_soft_border(1, &[24]));
        assert!(!g.in_soft_border(1, &[39])); // domain edge
    }

    #[test]
    fn box_difference_frame() {
        let ext = Rect::new(vec![0, 0], vec![10, 10]);
        let core = Rect::new(vec![3, 3], vec![7, 7]);
        let parts = box_difference(&ext, &core);
        let total: usize = parts.iter().map(|r| r.size()).sum();
        assert_eq!(total, 100 - 16);
        // disjoint & exclude core
        let mut seen = std::collections::HashSet::new();
        for r in &parts {
            for pt in r.iter() {
                assert!(!core.contains(&pt));
                assert!(seen.insert(pt));
            }
        }
    }

    #[test]
    fn box_difference_core_outside() {
        let ext = Rect::new(vec![0], vec![5]);
        let core = Rect::new(vec![10], vec![12]);
        let parts = box_difference(&ext, &core);
        assert_eq!(parts.iter().map(|r| r.size()).sum::<usize>(), 5);
    }

    #[test]
    fn v_box_shape() {
        let g = WorkerGrid::new(&[50, 50], &[3, 5], 4, PartitionKind::Grid);
        let v = g.v_box(&[10, 20]);
        assert_eq!(v, Rect::new(vec![8, 16], vec![13, 25]));
    }

    #[test]
    fn owner_of_boundaries() {
        let g = WorkerGrid::new(&[30], &[4], 3, PartitionKind::Line);
        assert_eq!(g.owner_of(&[0]), 0);
        assert_eq!(g.owner_of(&[9]), 0);
        assert_eq!(g.owner_of(&[10]), 1);
        assert_eq!(g.owner_of(&[29]), 2);
    }

    #[test]
    #[should_panic]
    fn too_many_workers_panics() {
        let _ = WorkerGrid::new(&[4], &[2], 8, PartitionKind::Line);
    }
}
