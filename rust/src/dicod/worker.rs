//! The resident DiCoDiLe-Z worker (Algorithm 3 of the paper, made
//! persistent across the full CDL alternation).
//!
//! Each worker owns a contiguous sub-domain `S_w` of the activation
//! domain and maintains, for its whole lifetime:
//!
//! - `beta` on the extended window `S_w + (L-1)` (the `Theta`-extension
//!   the soft-lock rule inspects),
//! - `Z` on the wider window `S_w + 2(L-1)` — the extra `L-1` rim holds
//!   every neighbour activation whose support reaches the beta window,
//!   which is exactly what the warm beta re-initialization under a new
//!   dictionary (`SetDict`) needs. The rim costs nothing extra in
//!   traffic: an update's V-box overlaps our extended window iff the
//!   update lies inside this rim, so the existing notification rule
//!   already delivers every value the rim stores.
//!
//! During a `Solve` phase the worker runs locally-greedy coordinate
//! descent on its own cell, rejects candidates that lose the
//! decentralized *soft-lock* comparison (eq. 14) against the extension,
//! notifies neighbours whose windows its accepted updates reach, and
//! participates in the counter-based termination protocol (workers
//! pause when locally converged and resume on incoming messages — §4.1
//! "workers that reach this state are paused"). Between phases it sits
//! on its inbox, applying any late neighbour notifications so its
//! windows stay consistent, and serves `ComputeStats` / `SetDict` /
//! `Gather` commands from its resident state.
//!
//! Two alternation modes drive the phase protocol (see
//! [`crate::dicod::config::Alternation`]). Under the default *barrier*
//! alternation `SetDict` is applied by the dispatcher strictly between
//! phases. Under *pipelined* alternation the pool issues `ResumeSolve`
//! right after collecting the φ/ψ partials: the worker re-enters the
//! solve loop speculatively under the old dictionary (its resident
//! Z/beta sit at the previous fixed point, so speculative updates are
//! ordinary warm coordinate descent), and the eventual `SetDict` lands
//! *mid-solve*, applied inside the loop as the same warm beta re-init +
//! dirty-all-segments rebuild, after which convergence is re-proved
//! under the new dictionary before the phase can end. The
//! speculative-solve invariant: a mid-solve swap is the same state
//! transition as a between-phase swap — only its timing differs.
//!
//! Segment selection runs through the worker's resident
//! [`SelectionState`] (see `csc::select`): clean segments answer their
//! visit from a cached champion in O(1) and only segments dirtied by a
//! local update, a neighbour's notification, or a `SetDict` beta
//! rebuild pay a rescan — observable via the `segments_skipped` /
//! `segments_rescanned` worker counters, and toggleable back to the
//! always-rescan path with `DICODILE_SELECT=rescan`. The soft-lock
//! comparison reads the same cached `dz_opt` (the cache covers the full
//! extended window, kept exactly fresh by the fused updates), so a
//! border candidate's `V(u0) ∩ E(S_w)` max costs cached reads instead
//! of beta recomputation.
//!
//! All messaging goes through a [`WorkerEndpoint`]
//! (see [`crate::dicod::transport`]): the worker never holds a channel
//! or a socket, only its endpoint and the transport-addressable
//! neighbour ids ([`NeighborLink`]), so the same loop runs unchanged
//! over in-process channels, loopback sockets, or a served
//! `dicodile worker --listen` connection.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::csc::beta::{BetaWindow, ZWindow};
use crate::csc::problem::CscProblem;
use crate::csc::select::{Segments, SelectMode, SelectionState, Strategy};
use crate::dicod::config::DicodConfig;
use crate::dicod::messages::{
    CoordMsg, DictUpdate, DoneMsg, SetDictMsg, SetProblemMsg, SolveDoneMsg, StatsMsg, StatusMsg,
    UpdateMsg, WorkerMsg, WorkerStats,
};
use crate::dicod::partition::{box_difference, NeighborLink, WorkerGrid};
use crate::dicod::transport::{RecvError, WorkerEndpoint};
use crate::tensor::shape::Rect;
use crate::tensor::NdTensor;

/// Everything a resident worker thread is born with.
pub struct PoolWorkerCtx {
    pub rank: usize,
    pub problem: Arc<CscProblem>,
    pub grid: Arc<WorkerGrid>,
    pub cfg: Arc<DicodConfig>,
    /// The worker's side of the transport seam: inbox + all sends.
    pub endpoint: Box<dyn WorkerEndpoint>,
    /// Transport-addressable neighbour topology.
    pub peers: Vec<NeighborLink>,
    /// Optional full-domain warm-start activation.
    pub z0: Option<Arc<NdTensor>>,
}

/// Poll period while paused (waiting for neighbour traffic or Stop).
const IDLE_POLL: Duration = Duration::from_millis(2);

/// Run the resident worker until `Shutdown` (or transport teardown).
pub fn run_pool_worker(ctx: PoolWorkerCtx) {
    let PoolWorkerCtx { rank, mut problem, grid, cfg, mut endpoint, peers, z0 } = ctx;
    let cell = grid.cell(rank);
    let ext = grid.extended_cell(rank);
    let ext_dims = ext.extents();
    let k_tot = problem.n_atoms();
    let zsp = problem.z_spatial_dims();

    // Z lives on the cell dilated by 2(L-1): extension + warm-reinit rim.
    let rim: Vec<usize> = problem.atom_dims().iter().map(|&l| 2 * (l - 1)).collect();
    let zwin = cell.dilate(&rim).intersect(&Rect::full(&zsp));
    let mut z = ZWindow::zeros(k_tot, &zwin.lo, &zwin.extents());

    let mut stats = WorkerStats::default();

    // Beta bootstrap on the extended window, dispatched through the
    // problem's CorrEngine so same-size worker windows share FFT plans
    // and the per-padded-size dictionary spectra.
    let mut beta = match &z0 {
        Some(z0) => {
            z.load_from_global(z0);
            stats.beta_warm_inits += 1;
            BetaWindow::init_window_warm(&problem, &ext.lo, &ext_dims, &z)
        }
        None => {
            stats.beta_cold_inits += 1;
            BetaWindow::init_window(&problem, &ext.lo, &ext_dims)
        }
    };

    // Local segments C_m^(w) over the worker's own cell, owned by the
    // selection state: clean segments answer their visit from a cached
    // champion in O(1); remote updates and `SetDict` re-inits mark the
    // overlapped segments dirty (see `csc::select`).
    let segs = match cfg.strategy {
        Strategy::Greedy => Segments::new(cell.clone(), &cell.extents()),
        _ => Segments::for_atoms(cell.clone(), problem.atom_dims()),
    };
    let mut sel = SelectionState::new(cfg.select, segs, &problem, &beta, &z);
    // The incremental cache build is real work: charge it to the
    // simulated clock so the scaling figures stay honest.
    stats.work += sel.coords_cache_filled;
    // The extension E(S_w) = ext \ cell, decomposed into boxes for the
    // soft-lock max computation.
    let ext_parts = box_difference(&ext, &cell);

    // ---- phase dispatcher ------------------------------------------------
    loop {
        match endpoint.recv() {
            Err(_) => break,
            // Late neighbour notification from the previous solve phase:
            // apply it so beta/Z stay consistent (and the Safra balance
            // settles) before the next phase command, which the FIFO
            // inbox guarantees is behind it.
            Ok(WorkerMsg::Update(u)) => {
                apply_remote_update(&problem, &mut beta, &mut z, &mut sel, &u, &mut stats)
            }
            // Stray Stop (e.g. a timeout race after the phase already
            // ended): nothing to do outside a solve phase.
            Ok(WorkerMsg::Stop) => {}
            // `ResumeSolve` is the pipelined-alternation re-entry: same
            // loop, warm from the resident windows, with the `SetDict`
            // broadcast expected to land mid-phase.
            Ok(WorkerMsg::Solve) | Ok(WorkerMsg::ResumeSolve) => {
                stats.solves += 1;
                let alive = solve_phase(SolveCtx {
                    rank,
                    problem: &mut problem,
                    grid: grid.as_ref(),
                    cfg: cfg.as_ref(),
                    endpoint: endpoint.as_mut(),
                    peers: &peers,
                    beta: &mut beta,
                    z: &mut z,
                    sel: &mut sel,
                    ext: &ext,
                    ext_dims: &ext_dims,
                    ext_parts: &ext_parts,
                    stats: &mut stats,
                });
                endpoint
                    .send_coord(CoordMsg::SolveDone(SolveDoneMsg { from: rank, stats: stats.clone() }));
                if !alive {
                    break;
                }
            }
            Ok(WorkerMsg::ComputeStats) => {
                let (phi, psi, z_l1, z_nnz) =
                    crate::dict::phi_psi::worker_stats_partials(&problem, &z, &cell, &ext);
                endpoint.send_coord(CoordMsg::Stats(StatsMsg { from: rank, phi, psi, z_l1, z_nnz }));
            }
            Ok(WorkerMsg::SetDict(msg)) => {
                apply_set_dict(
                    rank,
                    &mut problem,
                    msg,
                    &ext,
                    &ext_dims,
                    &z,
                    &mut beta,
                    &mut sel,
                    &mut stats,
                    endpoint.as_mut(),
                );
            }
            Ok(WorkerMsg::SetProblem(msg)) => {
                // Streaming chunk swap: new observation (and possibly a
                // new dictionary/λ) on an *unchanged* geometry — the
                // cell/extension/window rectangles computed at spawn
                // stay valid, so the worker replays its bootstrap
                // in place instead of being respawned.
                let (p_new, z0_new) = match msg {
                    SetProblemMsg::Shared { problem: p, z0 } => (p, z0),
                    SetProblemMsg::Wire(pu) => (
                        Arc::new(CscProblem::new(pu.x, pu.d, pu.lambda)),
                        pu.z0.map(Arc::new),
                    ),
                };
                assert_eq!(
                    p_new.z_spatial_dims(),
                    zsp,
                    "worker {rank}: SetProblem must preserve the activation domain"
                );
                assert_eq!(
                    p_new.n_atoms(),
                    k_tot,
                    "worker {rank}: SetProblem must preserve the atom count"
                );
                assert_eq!(
                    p_new.atom_dims(),
                    problem.atom_dims(),
                    "worker {rank}: SetProblem must preserve the atom dims"
                );
                problem = p_new;
                // The resident Z belongs to the *previous* observation:
                // reset it, optionally to the broadcast warm start (the
                // stitching holdback from the preceding chunk).
                z = ZWindow::zeros(k_tot, &zwin.lo, &zwin.extents());
                beta = match &z0_new {
                    Some(z0) => {
                        z.load_from_global(z0);
                        stats.beta_warm_inits += 1;
                        BetaWindow::init_window_warm(&problem, &ext.lo, &ext_dims, &z)
                    }
                    None => {
                        stats.beta_cold_inits += 1;
                        BetaWindow::init_window(&problem, &ext.lo, &ext_dims)
                    }
                };
                let filled_before = sel.coords_cache_filled;
                sel.rebuild(&problem, &beta, &z);
                stats.work += sel.coords_cache_filled - filled_before;
                endpoint.send_coord(CoordMsg::ProblemSet { from: rank });
            }
            Ok(WorkerMsg::Gather) => {
                stats.gathers += 1;
                sync_selection_counters(&mut stats, &sel);
                let z_cell = extract_cell(&z, &cell, k_tot);
                endpoint
                    .send_coord(CoordMsg::Done(DoneMsg { from: rank, z_cell, stats: stats.clone() }));
            }
            Ok(WorkerMsg::Shutdown) => break,
        }
    }
}

/// Borrowed state for one solve phase. `problem` is mutable because a
/// pipelined `SetDict` can land mid-phase and swap it in place; `ext` /
/// `ext_dims` are carried so the mid-solve warm beta re-init can run
/// without leaving the loop.
struct SolveCtx<'a> {
    rank: usize,
    problem: &'a mut Arc<CscProblem>,
    grid: &'a WorkerGrid,
    cfg: &'a DicodConfig,
    endpoint: &'a mut dyn WorkerEndpoint,
    peers: &'a [NeighborLink],
    beta: &'a mut BetaWindow,
    z: &'a mut ZWindow,
    sel: &'a mut SelectionState,
    ext: &'a Rect,
    ext_dims: &'a [usize],
    ext_parts: &'a [Rect],
    stats: &'a mut WorkerStats,
}

/// Apply a dictionary broadcast to the resident state: swap the
/// problem, re-bootstrap beta warm from the resident Z, refresh the
/// selection cache (dirtying every segment), and ack with `DictSet`.
/// Called from the phase dispatcher (barrier alternation: between
/// phases) and from inside [`solve_phase`] (pipelined alternation: the
/// broadcast lands mid-solve) — the speculative-solve invariant is
/// that both paths run exactly this transition.
#[allow(clippy::too_many_arguments)]
fn apply_set_dict(
    rank: usize,
    problem: &mut Arc<CscProblem>,
    msg: SetDictMsg,
    ext: &Rect,
    ext_dims: &[usize],
    z: &ZWindow,
    beta: &mut BetaWindow,
    sel: &mut SelectionState,
    stats: &mut WorkerStats,
    endpoint: &mut dyn WorkerEndpoint,
) {
    *problem = match msg {
        // In-process delivery: share the coordinator's problem (FFT
        // spectra included) by Arc.
        SetDictMsg::Shared(p) => p,
        // Wire delivery: rebuild a local CscProblem against the
        // resident X. Derived quantities (DtD, norms, beta) are
        // bit-identical to the shared path; the FFT spectra are
        // regenerated on this host — a once-per-host cost the channel
        // transport never pays (see the messages module docs).
        SetDictMsg::Wire(du) => {
            assert_eq!(
                du.fingerprint,
                DictUpdate::geometry_fingerprint(problem.x.dims(), du.d.dims()),
                "worker {rank}: SetDict geometry fingerprint mismatch"
            );
            Arc::new(CscProblem::new(problem.x_shared(), du.d, du.lambda))
        }
    };
    *beta = BetaWindow::init_window_warm(problem, &ext.lo, ext_dims, z);
    // beta was rebuilt wholesale under the new dictionary: refresh the
    // dz_opt cache (charged to the simulated clock) and dirty every
    // segment.
    let filled_before = sel.coords_cache_filled;
    sel.rebuild(problem, beta, z);
    stats.work += sel.coords_cache_filled - filled_before;
    stats.beta_warm_reinits += 1;
    endpoint.send_coord(CoordMsg::DictSet { from: rank });
}

/// Send a status report on the worker→coordinator edge (free function
/// so it can borrow the endpoint mutably between inbox polls).
fn send_status(
    endpoint: &mut dyn WorkerEndpoint,
    rank: usize,
    idle: bool,
    converged: bool,
    diverged: bool,
    stats: &WorkerStats,
) {
    endpoint.send_coord(CoordMsg::Status(StatusMsg {
        from: rank,
        idle,
        sent: stats.msgs_sent,
        received: stats.msgs_received,
        converged,
        diverged,
    }));
}

/// One solve phase: DiCoDiLe-Z from the resident windows, until the
/// coordinator's `Stop`. Returns `false` if the worker should exit
/// entirely (Shutdown or transport teardown mid-phase).
fn solve_phase(ctx: SolveCtx<'_>) -> bool {
    let SolveCtx {
        rank,
        problem,
        grid,
        cfg,
        endpoint,
        peers,
        beta,
        z,
        sel,
        ext,
        ext_dims,
        ext_parts,
        stats,
    } = ctx;
    let m_tot = sel.n_segments();
    let max_updates = (cfg.max_updates / grid.n_workers().max(1)).max(1) as u64;
    let deadline = Instant::now() + Duration::from_secs_f64(cfg.timeout);

    // Per-phase state — the counter-reset rule: the update cap, the
    // divergence flag, the sweep position and the deadline are local to
    // the phase; the Safra message counters (in `stats`) are cumulative.
    let mut m = 0usize;
    let mut sweep_max = 0.0f64;
    let mut idle = false;
    let mut capped = false;
    let mut diverged = false;
    let mut phase_updates = 0u64;
    // Updates already counted as speculative this phase: everything
    // accepted before a mid-solve `SetDict` ran under the dictionary
    // that broadcast just retired.
    let mut spec_baseline = 0u64;
    let mut stop = false;
    let mut alive = true;

    let inbox_every = cfg.inbox_every.max(1);
    let mut since_drain = 0usize;

    'main: loop {
        // -- 1. drain the inbox (possibly delayed, emulating network
        //       latency — see DicodConfig::inbox_every) ------------------
        since_drain += 1;
        let drain_now = idle || since_drain >= inbox_every;
        while drain_now {
            match endpoint.try_recv() {
                Ok(WorkerMsg::Update(u)) => {
                    apply_remote_update(problem, beta, z, sel, &u, stats);
                    if idle {
                        if !capped && !diverged {
                            idle = false;
                            sweep_max = 0.0;
                            send_status(endpoint, rank, false, false, false, stats);
                        } else {
                            // Still paused (capped/diverged), but the
                            // received counter moved: refresh it so the
                            // coordinator's Safra balance can settle
                            // instead of stalling until the timeout.
                            send_status(endpoint, rank, true, false, diverged, stats);
                        }
                    }
                }
                Ok(WorkerMsg::Stop) => {
                    stop = true;
                    break;
                }
                Ok(WorkerMsg::Shutdown) => {
                    stop = true;
                    alive = false;
                    break;
                }
                // Pipelined alternation: the dictionary broadcast lands
                // mid-solve. Apply the warm re-init in place and keep
                // solving; convergence must be re-proved under the new
                // dictionary, so the sweep tracker restarts and an idle
                // worker wakes.
                Ok(WorkerMsg::SetDict(msg)) => {
                    apply_set_dict(rank, problem, msg, ext, ext_dims, z, beta, sel, stats, endpoint);
                    stats.overlap_updates += phase_updates - spec_baseline;
                    spec_baseline = phase_updates;
                    sweep_max = 0.0;
                    if idle {
                        if !capped && !diverged {
                            idle = false;
                            send_status(endpoint, rank, false, false, false, stats);
                        } else {
                            send_status(endpoint, rank, true, false, diverged, stats);
                        }
                    }
                }
                // Other phase commands never overlap a solve (the pool
                // waits for SolveDone); ignore defensively.
                Ok(_) => {}
                Err(_) => break,
            }
        }
        if drain_now {
            since_drain = 0;
        }
        if stop {
            break 'main;
        }
        if Instant::now() > deadline {
            // Report and wait for the coordinator's Stop.
            if !idle {
                idle = true;
                send_status(endpoint, rank, true, false, diverged, stats);
            }
        }

        // -- 2. paused: block briefly on the inbox ------------------------
        if idle {
            match endpoint.recv_timeout(IDLE_POLL) {
                Ok(WorkerMsg::Update(u)) => {
                    apply_remote_update(problem, beta, z, sel, &u, stats);
                    if !capped && !diverged {
                        idle = false;
                        sweep_max = 0.0;
                        send_status(endpoint, rank, false, false, false, stats);
                    } else {
                        // See the drain branch: keep the coordinator's
                        // received counter fresh while pause persists.
                        send_status(endpoint, rank, true, false, diverged, stats);
                    }
                }
                Ok(WorkerMsg::Stop) => break 'main,
                Ok(WorkerMsg::Shutdown) => {
                    alive = false;
                    break 'main;
                }
                // Mid-solve dictionary broadcast while paused (see the
                // drain branch): re-init warm and wake to re-prove
                // convergence under the new dictionary.
                Ok(WorkerMsg::SetDict(msg)) => {
                    apply_set_dict(rank, problem, msg, ext, ext_dims, z, beta, sel, stats, endpoint);
                    stats.overlap_updates += phase_updates - spec_baseline;
                    spec_baseline = phase_updates;
                    sweep_max = 0.0;
                    if !capped && !diverged {
                        idle = false;
                        send_status(endpoint, rank, false, false, false, stats);
                    } else {
                        send_status(endpoint, rank, true, false, diverged, stats);
                    }
                }
                Ok(_) => {}
                Err(RecvError::Timeout) => {}
                // `Empty` cannot come out of a blocking receive;
                // anything else means the grid is gone.
                Err(_) => {
                    alive = false;
                    break 'main;
                }
            }
            continue 'main;
        }

        // -- 3. one locally-greedy iteration on segment m -----------------
        // Clean segment -> cached champion in O(1); dirty -> rescan of
        // the cached dz_opt. `work` charges only the coordinates the
        // visit actually examined (in rescan mode the delta is the full
        // K·|C_m| scan, the pre-incremental accounting).
        stats.iterations += 1;
        let scanned_before = sel.coords_scanned;
        let candidate = sel.best_in_segment(problem, beta, z, m);
        stats.work += sel.coords_scanned - scanned_before;
        if let Some((k0, u0, dz0)) = candidate {
            if dz0.abs() >= cfg.tol {
                let accepted = if cfg.soft_lock && grid.in_soft_border(rank, &u0) {
                    let (ok, scanned) =
                        soft_lock_accepts(problem, grid, sel, beta, z, ext_parts, rank, &u0, dz0);
                    stats.work += scanned;
                    ok
                } else {
                    true
                };
                if accepted {
                    // Only *accepted* updates keep the sweep alive: a
                    // soft-locked candidate belongs to a neighbour's
                    // V-box, and that neighbour's eventual update will
                    // arrive as a message and wake us — pausing instead
                    // of spinning on blocked borders (crucial on dense
                    // images, where border candidates are plentiful).
                    sweep_max = sweep_max.max(dz0.abs());
                    stats.work += sel.apply_update(problem, beta, z, k0, &u0, dz0) as u64;
                    z.add_at(k0, &u0, dz0);
                    stats.updates += 1;
                    phase_updates += 1;

                    // Divergence guard (paper §5.1, Fig. 5 protocol).
                    if let Some(guard) = cfg.divergence_guard {
                        if z.at(k0, &u0).abs() > guard {
                            diverged = true;
                            idle = true;
                            send_status(endpoint, rank, true, false, true, stats);
                            continue 'main;
                        }
                    }

                    // Notify neighbours whose windows the V-box reaches.
                    let v = grid.v_box(&u0);
                    for peer in peers {
                        if v.overlaps(&peer.ext_window) {
                            stats.msgs_sent += 1;
                            endpoint.send_update(
                                peer.rank,
                                UpdateMsg { from: rank, k: k0, u: u0.clone(), dz: dz0 },
                            );
                        }
                    }

                    if phase_updates >= max_updates {
                        capped = true;
                        idle = true;
                        send_status(endpoint, rank, true, false, false, stats);
                        continue 'main;
                    }
                } else {
                    stats.soft_locked += 1;
                }
            }
        }

        // -- 4. sweep bookkeeping -----------------------------------------
        m += 1;
        if m == m_tot {
            m = 0;
            stats.sweeps += 1;
            if sweep_max < cfg.tol {
                idle = true;
                stats.pauses += 1;
                send_status(endpoint, rank, true, true, false, stats);
            }
            sweep_max = 0.0;
        }
    }
    sync_selection_counters(stats, sel);
    alive
}

/// Snapshot the selection state's cumulative counters into the worker
/// counters (assignment, not accumulation: both live for the worker's
/// whole lifetime).
fn sync_selection_counters(stats: &mut WorkerStats, sel: &SelectionState) {
    stats.segments_skipped = sel.segments_skipped;
    stats.segments_rescanned = sel.segments_rescanned;
    stats.dz_cache_filled = sel.coords_cache_filled;
}

/// Apply a neighbour's update notification to the local windows,
/// marking the segments its V-box overlaps dirty so their cached
/// champions are recomputed on the next visit.
fn apply_remote_update(
    problem: &CscProblem,
    beta: &mut BetaWindow,
    z: &mut ZWindow,
    sel: &mut SelectionState,
    msg: &UpdateMsg,
    stats: &mut WorkerStats,
) {
    stats.msgs_received += 1;
    stats.work += sel.apply_update(problem, beta, z, msg.k, &msg.u, msg.dz) as u64;
    if z.contains(&msg.u) {
        z.add_at(msg.k, &msg.u, msg.dz);
    }
}

/// The soft-lock acceptance test (eq. 14): the candidate at `u0` with
/// amplitude `dz0` is accepted iff no strictly better update exists in
/// `V(u0) ∩ E(S_w)`; on exact ties the lower worker rank wins.
/// Returns `(accepted, coordinates scanned)`.
///
/// In incremental selection mode the extension max is read from the
/// resident `dz_opt` cache — the fused updates keep the cache exactly
/// fresh over the *whole* extended window (the dirty flags only gate
/// the per-segment champion caches), so the cached read is bit-identical
/// to the fresh beta rescan while skipping the soft-threshold
/// recomputation. `DICODILE_SELECT=rescan` keeps the original scan.
/// Either way the scanned coordinates are charged to the simulated
/// clock by the caller: the candidates still have to be *compared*, and
/// keeping the accounting mode-independent keeps the scaling figures'
/// `work` comparable across selection modes.
#[allow(clippy::too_many_arguments)]
fn soft_lock_accepts(
    problem: &CscProblem,
    grid: &WorkerGrid,
    sel: &SelectionState,
    beta: &BetaWindow,
    z: &ZWindow,
    ext_parts: &[Rect],
    rank: usize,
    u0: &[i64],
    dz0: f64,
) -> (bool, u64) {
    let v = grid.v_box(u0);
    let mut best_abs = 0.0f64;
    let mut best_owner = usize::MAX;
    let mut scanned = 0u64;
    let cached = sel.mode() == SelectMode::Incremental;
    for part in ext_parts {
        let r = part.intersect(&v);
        if r.is_empty() {
            continue;
        }
        scanned += (problem.n_atoms() * r.size()) as u64;
        let cand = if cached {
            sel.cached_best_in_rect(beta, &r)
        } else {
            beta.best_candidate(problem, z, &r)
        };
        if let Some((_, u, dz)) = cand {
            if dz.abs() > best_abs {
                best_abs = dz.abs();
                best_owner = grid.owner_of(&u);
            }
        }
    }
    let accepted = if dz0.abs() > best_abs {
        true
    } else if dz0.abs() == best_abs {
        // Tie: the update in the lowest-ranked sub-domain is preferred.
        rank < best_owner
    } else {
        false
    };
    (accepted, scanned)
}

/// Copy the worker's own cell out of its (wider) Z window,
/// row-major over `[K, cell extents..]`.
fn extract_cell(z: &ZWindow, cell: &Rect, k_tot: usize) -> Vec<f64> {
    let cell_sp = cell.size();
    let mut out = vec![0.0; k_tot * cell_sp];
    for k in 0..k_tot {
        for (i, u) in cell.iter().enumerate() {
            out[k * cell_sp + i] = z.at(k, &u);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dicod::partition::PartitionKind;
    use crate::tensor::NdTensor;
    use crate::util::rng::Pcg64;

    fn toy_problem() -> CscProblem {
        let mut rng = Pcg64::seeded(1);
        let x = NdTensor::from_vec(&[1, 40], rng.normal_vec(40));
        let d = NdTensor::from_vec(&[2, 1, 5], rng.normal_vec(10));
        CscProblem::with_lambda_frac(x, d, 0.1)
    }

    #[test]
    fn extract_cell_reads_window() {
        let mut z = ZWindow::zeros(2, &[3], &[10]);
        z.add_at(0, &[5], 2.5);
        z.add_at(1, &[12], -1.0);
        let cell = Rect::new(vec![5], vec![13]);
        let out = extract_cell(&z, &cell, 2);
        assert_eq!(out.len(), 16);
        assert_eq!(out[0], 2.5); // k=0, u=5
        assert_eq!(out[8 + 7], -1.0); // k=1, u=12
    }

    /// Selection states in both modes, built *after* any planted beta
    /// values so the incremental dz_opt cache reflects them.
    fn both_modes(p: &CscProblem, cell: &Rect, beta: &BetaWindow, z: &ZWindow) -> [SelectionState; 2] {
        [SelectMode::Rescan, SelectMode::Incremental].map(|mode| {
            SelectionState::new(mode, Segments::for_atoms(cell.clone(), p.atom_dims()), p, beta, z)
        })
    }

    #[test]
    fn soft_lock_prefers_larger_candidate() {
        let p = toy_problem();
        let grid = WorkerGrid::new(&p.z_spatial_dims(), p.atom_dims(), 2, PartitionKind::Line);
        let ext = grid.extended_cell(0);
        let cell = grid.cell(0);
        let ext_parts = box_difference(&ext, &cell);
        // Build beta windows with controlled values: make the extension
        // hold a huge dz so any border candidate is locked.
        let mut beta = BetaWindow::init_window(&p, &ext.lo, &ext.extents());
        let z = ZWindow::zeros(p.n_atoms(), &ext.lo, &ext.extents());
        // extension of worker 0 = [20, 24); plant a large beta there
        let off = beta.local_offset(&[21]);
        beta.data[off] = 1e6;
        let u0 = vec![cell.hi[0] - 1]; // border coordinate
        assert!(grid.in_soft_border(0, &u0));
        let dz0 = 0.5;
        for sel in &both_modes(&p, &cell, &beta, &z) {
            let (ok, scanned) =
                soft_lock_accepts(&p, &grid, sel, &beta, &z, &ext_parts, 0, &u0, dz0);
            assert!(!ok);
            assert!(scanned > 0);
            // and accepted when the candidate dominates
            assert!(soft_lock_accepts(&p, &grid, sel, &beta, &z, &ext_parts, 0, &u0, 1e7).0);
        }
    }

    #[test]
    fn soft_lock_tie_breaks_by_rank() {
        let p = toy_problem();
        let grid = WorkerGrid::new(&p.z_spatial_dims(), p.atom_dims(), 2, PartitionKind::Line);
        let ext0 = grid.extended_cell(0);
        let cell0 = grid.cell(0);
        let parts0 = box_difference(&ext0, &cell0);
        let beta0 = BetaWindow::init_window(&p, &ext0.lo, &ext0.extents());
        let z0 = ZWindow::zeros(p.n_atoms(), &ext0.lo, &ext0.extents());
        // Find an actual tie: candidate amplitude == extension max.
        // Use the extension's own best as the tie value.
        let u0 = vec![cell0.hi[0] - 1];
        let v = grid.v_box(&u0);
        let mut ext_best = 0.0;
        for part in &parts0 {
            let r = part.intersect(&v);
            if r.is_empty() {
                continue;
            }
            if let Some((_, _, dz)) = beta0.best_candidate(&p, &z0, &r) {
                ext_best = f64::max(ext_best, dz.abs());
            }
        }
        if ext_best > 0.0 {
            for sel in &both_modes(&p, &cell0, &beta0, &z0) {
                // worker 0 (lower rank) wins ties
                assert!(soft_lock_accepts(&p, &grid, sel, &beta0, &z0, &parts0, 0, &u0, ext_best).0);
            }
        }
    }

    /// The cached (incremental) soft-lock scan and the fresh beta
    /// rescan must agree — accept/reject decision AND scanned count —
    /// on real correlated data at every border coordinate.
    #[test]
    fn soft_lock_cached_matches_rescan() {
        let p = toy_problem();
        let grid = WorkerGrid::new(&p.z_spatial_dims(), p.atom_dims(), 2, PartitionKind::Line);
        for rank in 0..2 {
            let ext = grid.extended_cell(rank);
            let cell = grid.cell(rank);
            let ext_parts = box_difference(&ext, &cell);
            let beta = BetaWindow::init_window(&p, &ext.lo, &ext.extents());
            let z = ZWindow::zeros(p.n_atoms(), &ext.lo, &ext.extents());
            let [res, inc] = both_modes(&p, &cell, &beta, &z);
            for u0 in cell.iter() {
                if !grid.in_soft_border(rank, &u0) {
                    continue;
                }
                for dz0 in [1e-9, 0.05, 0.8, 1e4] {
                    let a = soft_lock_accepts(&p, &grid, &res, &beta, &z, &ext_parts, rank, &u0, dz0);
                    let b = soft_lock_accepts(&p, &grid, &inc, &beta, &z, &ext_parts, rank, &u0, dz0);
                    assert_eq!(a, b, "rank {rank} u0 {u0:?} dz0 {dz0}");
                }
            }
        }
    }
}
