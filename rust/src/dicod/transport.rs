//! The transport seam: message delivery for the worker grid.
//!
//! The distributed runtime has exactly three protocol edges —
//! coordinator→worker phase commands, worker→coordinator replies
//! (status / stats / gather), and the hot worker→worker `UpdateMsg`
//! neighbour traffic. This module owns all three behind a pair of
//! endpoint traits so the rest of the runtime never touches a concrete
//! channel or socket:
//!
//! * [`WorkerEndpoint`] — what a worker holds: a blocking/polling inbox
//!   plus sends to a neighbour (`send_update`) and to the coordinator
//!   (`send_coord`).
//! * [`CoordEndpoint`] — what the pool holds: per-rank command sends
//!   plus a polling receive of worker replies.
//! * [`Transport`] — hands out each endpoint exactly once at spawn.
//!
//! Two implementations ship today:
//!
//! * [`ChannelTransport`] (default): today's in-process
//!   `std::sync::mpsc` wiring, moved behind the seam verbatim — message
//!   values (including the `Arc<CscProblem>` of a `SetDict` broadcast)
//!   are moved, never serialized, and the disconnect semantics the pool
//!   relies on are preserved: the coordinator endpoint deliberately
//!   holds *no* sender for the reply channel, so the pool's receive
//!   fails loudly the moment the last worker thread dies.
//! * [`SocketTransport`]: length-prefixed binary frames
//!   ([`crate::dicod::messages`] wire format) over a loopback socket
//!   pair per worker (Unix-domain where available, TCP elsewhere).
//!   Workers send `Coord` frames upstream and `Fwd` frames for
//!   neighbour updates; a coordinator-side hub demultiplexes — replies
//!   into the pool's receive queue, forwards into the destination
//!   worker's outbox. One writer thread per destination stream keeps
//!   frames atomic and per-edge FIFO causal: a worker's `Fwd` written
//!   before its `SolveDone` is routed before the coordinator can even
//!   see the `SolveDone`, so the between-phase Safra settlement holds
//!   exactly as in channel mode. Every message crosses the real
//!   serialization boundary (`SetDict` travels as a
//!   [`crate::dicod::messages::DictUpdate`] and the receiving worker
//!   rebuilds its `CscProblem`, regenerating spectra once per host), so
//!   loopback CI runs exercise the same code path a multi-machine grid
//!   would.
//!
//! [`serve_worker_listen`] is the other half of the multi-process
//! story: `dicodile worker --listen <addr>` accepts one connection,
//! reads a `Bootstrap` frame (rank + config + problem data) and runs
//! the standard worker loop over that socket. This PR exercises it over
//! same-host sockets (see `tests/transport_parity.rs`); pool-side
//! remote attach (assembling a grid from served workers) is the next
//! step on ROADMAP direction 4 and intentionally out of scope here.

use std::io::{Read, Write};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::csc::problem::CscProblem;
use crate::csc::select::{SelectMode, Strategy};
use crate::dicod::config::{Alternation, DicodConfig};
use crate::dicod::messages::{
    decode_frame, encode_bootstrap_frame, encode_coord_frame, encode_fwd_frame,
    encode_worker_frame, BootstrapMsg, CoordMsg, UpdateMsg, WireFrame, WorkerMsg,
};
use crate::dicod::partition::{PartitionKind, WorkerGrid};
use crate::dicod::worker::{run_pool_worker, PoolWorkerCtx};
use crate::tensor::NdTensor;

/// Which transport a pool's grid runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process `mpsc` channels (zero-copy message moves).
    Channel,
    /// Length-prefixed binary frames over loopback sockets.
    Socket,
}

impl TransportKind {
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Channel => "channel",
            TransportKind::Socket => "socket",
        }
    }

    /// Honour the `DICODILE_TRANSPORT` env toggle (default: channel).
    /// Unknown values fall back to the default with a (once-only)
    /// warning rather than aborting — a silent fallback would turn a
    /// typo'd `socket` parity run into a bogus channel-vs-channel one.
    pub fn from_env() -> TransportKind {
        match std::env::var("DICODILE_TRANSPORT").ok().as_deref() {
            Some(s) => s.parse().unwrap_or_else(|e: String| {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| {
                    eprintln!("warning: DICODILE_TRANSPORT: {e}; defaulting to channel")
                });
                TransportKind::Channel
            }),
            None => TransportKind::Channel,
        }
    }
}

impl std::str::FromStr for TransportKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "channel" => Ok(TransportKind::Channel),
            "socket" => Ok(TransportKind::Socket),
            other => Err(format!("unknown transport {other:?} (channel|socket)")),
        }
    }
}

/// Receive failure, unified across transports. `Empty` only from
/// `try_recv`, `Timeout` only from `recv_timeout`; `Closed` means the
/// other side of the edge is gone for good.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecvError {
    Empty,
    Timeout,
    Closed,
}

/// A worker's view of the grid: its command/notification inbox plus
/// sends to neighbours and to the coordinator.
pub trait WorkerEndpoint: Send {
    /// Block until the next message (or `Closed`).
    fn recv(&mut self) -> Result<WorkerMsg, RecvError>;
    /// Non-blocking poll (`Empty` when the inbox is drained).
    fn try_recv(&mut self) -> Result<WorkerMsg, RecvError>;
    /// Block up to `timeout` (the worker's idle poll).
    fn recv_timeout(&mut self, timeout: Duration) -> Result<WorkerMsg, RecvError>;
    /// Notify neighbour `to` of a coordinate update. Best-effort: a
    /// dead neighbour is the pool's problem to detect, not the hot
    /// loop's.
    fn send_update(&mut self, to: usize, msg: UpdateMsg);
    /// Reply to the coordinator (status / stats / gather edges).
    fn send_coord(&mut self, msg: CoordMsg);
}

/// The pool's view of the grid: per-rank command sends plus the merged
/// reply stream.
pub trait CoordEndpoint: Send {
    /// Send a phase command (or routed update) to worker `rank`.
    fn send(&mut self, rank: usize, msg: WorkerMsg);
    /// Send the same phase command to ranks `0..n`. The default is a
    /// per-rank `send` loop (for the channel transport that is already
    /// just `n` cheap `Arc` clones); transports with a serialization
    /// seam override this to encode the payload once and share the
    /// frame bytes across ranks.
    fn broadcast(&mut self, n: usize, msg: WorkerMsg) {
        for rank in 0..n {
            self.send(rank, msg.clone());
        }
    }
    /// Wait up to `timeout` for the next worker reply. `Closed` means
    /// every worker endpoint is gone — the pool treats that as a dead
    /// grid and panics loudly.
    fn recv_timeout(&mut self, timeout: Duration) -> Result<CoordMsg, RecvError>;
}

/// Builds the endpoints for one pool spawn. Each endpoint is taken
/// exactly once; the transport object itself is dropped once the grid
/// is up (for `ChannelTransport` that drop is what severs the master
/// reply-sender so worker death disconnects the pool).
pub trait Transport {
    fn kind(&self) -> TransportKind;
    fn take_worker_endpoint(&mut self, rank: usize) -> Box<dyn WorkerEndpoint>;
    fn take_coord_endpoint(&mut self) -> Box<dyn CoordEndpoint>;
}

/// Construct the transport selected by `kind` for an `n_workers` grid.
pub fn make_transport(kind: TransportKind, n_workers: usize) -> Box<dyn Transport> {
    match kind {
        TransportKind::Channel => Box::new(ChannelTransport::new(n_workers)),
        TransportKind::Socket => Box::new(SocketTransport::new(n_workers)),
    }
}

// ---------------------------------------------------------------------------
// ChannelTransport: in-process mpsc, the default
// ---------------------------------------------------------------------------

/// Today's in-process wiring behind the seam: one `mpsc` inbox per
/// worker (commands and neighbour updates share it, preserving FIFO
/// causality) and one shared reply channel to the pool.
pub struct ChannelTransport {
    worker_tx: Vec<Sender<WorkerMsg>>,
    inboxes: Vec<Option<Receiver<WorkerMsg>>>,
    coord_tx: Sender<CoordMsg>,
    coord_rx: Option<Receiver<CoordMsg>>,
}

impl ChannelTransport {
    pub fn new(n_workers: usize) -> Self {
        let mut worker_tx = Vec::with_capacity(n_workers);
        let mut inboxes = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let (tx, rx) = mpsc::channel();
            worker_tx.push(tx);
            inboxes.push(Some(rx));
        }
        let (coord_tx, coord_rx) = mpsc::channel();
        ChannelTransport { worker_tx, inboxes, coord_tx, coord_rx: Some(coord_rx) }
    }
}

impl Transport for ChannelTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Channel
    }

    fn take_worker_endpoint(&mut self, rank: usize) -> Box<dyn WorkerEndpoint> {
        Box::new(ChannelWorkerEndpoint {
            inbox: self.inboxes[rank].take().expect("worker endpoint taken twice"),
            worker_tx: self.worker_tx.clone(),
            coord_tx: self.coord_tx.clone(),
        })
    }

    fn take_coord_endpoint(&mut self) -> Box<dyn CoordEndpoint> {
        // No `coord_tx` clone in here: only worker endpoints may hold
        // reply senders, so `recv_timeout` disconnects — and the pool
        // fails loudly — as soon as the last worker thread exits.
        Box::new(ChannelCoordEndpoint {
            worker_tx: self.worker_tx.clone(),
            coord_rx: self.coord_rx.take().expect("coord endpoint taken twice"),
        })
    }
}

struct ChannelWorkerEndpoint {
    inbox: Receiver<WorkerMsg>,
    worker_tx: Vec<Sender<WorkerMsg>>,
    coord_tx: Sender<CoordMsg>,
}

impl WorkerEndpoint for ChannelWorkerEndpoint {
    fn recv(&mut self) -> Result<WorkerMsg, RecvError> {
        self.inbox.recv().map_err(|_| RecvError::Closed)
    }

    fn try_recv(&mut self) -> Result<WorkerMsg, RecvError> {
        self.inbox.try_recv().map_err(|e| match e {
            TryRecvError::Empty => RecvError::Empty,
            TryRecvError::Disconnected => RecvError::Closed,
        })
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<WorkerMsg, RecvError> {
        self.inbox.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => RecvError::Timeout,
            RecvTimeoutError::Disconnected => RecvError::Closed,
        })
    }

    fn send_update(&mut self, to: usize, msg: UpdateMsg) {
        let _ = self.worker_tx[to].send(WorkerMsg::Update(msg));
    }

    fn send_coord(&mut self, msg: CoordMsg) {
        let _ = self.coord_tx.send(msg);
    }
}

struct ChannelCoordEndpoint {
    worker_tx: Vec<Sender<WorkerMsg>>,
    coord_rx: Receiver<CoordMsg>,
}

impl CoordEndpoint for ChannelCoordEndpoint {
    fn send(&mut self, rank: usize, msg: WorkerMsg) {
        let _ = self.worker_tx[rank].send(msg);
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<CoordMsg, RecvError> {
        self.coord_rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => RecvError::Timeout,
            RecvTimeoutError::Disconnected => RecvError::Closed,
        })
    }
}

// ---------------------------------------------------------------------------
// Frame I/O
// ---------------------------------------------------------------------------

/// Upper bound on a single frame payload (sanity guard against a
/// corrupt length prefix; 1 GiB comfortably fits any Bootstrap).
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// Write one `u32`-length-prefixed frame as a single `write_all` (the
/// one-writer-per-stream invariant makes that atomic on the wire).
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> std::io::Result<()> {
    if payload.len() > MAX_FRAME_LEN {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "frame payload too large",
        ));
    }
    let mut frame = Vec::with_capacity(4 + payload.len());
    frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    frame.extend_from_slice(payload);
    w.write_all(&frame)
}

/// Read one frame payload. `Ok(None)` on clean EOF at a frame
/// boundary; EOF inside a frame, oversized lengths and I/O failures
/// are errors.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<Option<Vec<u8>>> {
    let mut len = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof inside frame header",
                ))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_FRAME_LEN {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "frame length exceeds cap",
        ));
    }
    let mut payload = vec![0u8; n];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

// ---------------------------------------------------------------------------
// Duplex: one stream type over UDS and TCP
// ---------------------------------------------------------------------------

/// A connected byte stream — Unix-domain where the platform has it,
/// TCP otherwise (and for `worker --listen host:port`).
enum Duplex {
    #[cfg(unix)]
    Unix(std::os::unix::net::UnixStream),
    Tcp(std::net::TcpStream),
}

impl Duplex {
    /// A connected loopback pair (the per-worker link of
    /// `SocketTransport`).
    fn pair() -> std::io::Result<(Duplex, Duplex)> {
        #[cfg(unix)]
        {
            let (a, b) = std::os::unix::net::UnixStream::pair()?;
            Ok((Duplex::Unix(a), Duplex::Unix(b)))
        }
        #[cfg(not(unix))]
        {
            let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?;
            let a = std::net::TcpStream::connect(addr)?;
            let (b, _) = listener.accept()?;
            let _ = a.set_nodelay(true);
            let _ = b.set_nodelay(true);
            Ok((Duplex::Tcp(a), Duplex::Tcp(b)))
        }
    }

    fn try_clone(&self) -> std::io::Result<Duplex> {
        match self {
            #[cfg(unix)]
            Duplex::Unix(s) => s.try_clone().map(Duplex::Unix),
            Duplex::Tcp(s) => s.try_clone().map(Duplex::Tcp),
        }
    }

    /// Tear down the underlying socket (affects every clone): unblocks
    /// any thread parked in a read on either side. This is what breaks
    /// the reader-thread cycles at endpoint drop.
    fn shutdown_both(&self) {
        match self {
            #[cfg(unix)]
            Duplex::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            Duplex::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Duplex {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Duplex::Unix(s) => s.read(buf),
            Duplex::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Duplex {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            #[cfg(unix)]
            Duplex::Unix(s) => s.write(buf),
            Duplex::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            #[cfg(unix)]
            Duplex::Unix(s) => s.flush(),
            Duplex::Tcp(s) => s.flush(),
        }
    }
}

// ---------------------------------------------------------------------------
// SocketTransport: framed loopback sockets with a coordinator-side hub
// ---------------------------------------------------------------------------

/// Socket-backed transport: one loopback stream pair per worker, a
/// star topology with the coordinator-side hub routing worker→worker
/// `Fwd` frames. Every message is encoded to the wire format — this is
/// the exact data path a multi-process grid runs, minus the physical
/// network.
pub struct SocketTransport {
    worker_streams: Vec<Option<Duplex>>,
    hub_streams: Vec<Option<Duplex>>,
}

impl SocketTransport {
    pub fn new(n_workers: usize) -> Self {
        let mut worker_streams = Vec::with_capacity(n_workers);
        let mut hub_streams = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let (hub, worker) = Duplex::pair().expect("socket transport: loopback pair");
            hub_streams.push(Some(hub));
            worker_streams.push(Some(worker));
        }
        SocketTransport { worker_streams, hub_streams }
    }
}

impl Transport for SocketTransport {
    fn kind(&self) -> TransportKind {
        TransportKind::Socket
    }

    fn take_worker_endpoint(&mut self, rank: usize) -> Box<dyn WorkerEndpoint> {
        let stream = self.worker_streams[rank].take().expect("worker endpoint taken twice");
        Box::new(SocketWorkerEndpoint::over(stream))
    }

    fn take_coord_endpoint(&mut self) -> Box<dyn CoordEndpoint> {
        let n = self.hub_streams.len();
        let (coord_tx, coord_rx) = mpsc::channel::<CoordMsg>();
        let mut streams = Vec::with_capacity(n);
        let mut outbox = Vec::with_capacity(n);
        let mut writers = Vec::with_capacity(n);
        for rank in 0..n {
            let stream = self.hub_streams[rank].take().expect("coord endpoint taken twice");
            let mut wh = stream.try_clone().expect("socket transport: clone write half");
            let (tx, rx) = mpsc::channel::<Vec<u8>>();
            // One writer thread per destination stream: frames from the
            // pool and routed neighbour updates interleave FIFO here.
            writers.push(std::thread::spawn(move || {
                while let Ok(payload) = rx.recv() {
                    if write_frame(&mut wh, &payload).is_err() {
                        break;
                    }
                }
            }));
            outbox.push(tx);
            streams.push(stream);
        }
        let mut readers = Vec::with_capacity(n);
        for stream in &streams {
            let mut rh = stream.try_clone().expect("socket transport: clone read half");
            let coord_tx = coord_tx.clone();
            let outboxes = outbox.clone();
            // One reader (demux) thread per worker stream: replies go
            // to the pool's queue, `Fwd` frames to the destination
            // outbox. Exits on EOF — when every reader is gone the
            // pool's queue disconnects, mirroring the channel
            // transport's dead-grid detection.
            readers.push(std::thread::spawn(move || loop {
                match read_frame(&mut rh) {
                    Ok(Some(payload)) => match decode_frame(&payload) {
                        Ok(WireFrame::Coord(m)) => {
                            if coord_tx.send(m).is_err() {
                                break;
                            }
                        }
                        Ok(WireFrame::Fwd { to, msg }) => {
                            if to < outboxes.len() {
                                let _ = outboxes[to]
                                    .send(encode_worker_frame(&WorkerMsg::Update(msg)));
                            }
                        }
                        // A worker has no business sending anything
                        // else upstream: treat it as a dead link.
                        Ok(_) | Err(_) => break,
                    },
                    Ok(None) | Err(_) => break,
                }
            }));
        }
        // `coord_tx` master clone drops here: only reader threads hold
        // reply senders, so worker death cascades to `Closed` exactly
        // like the channel transport.
        Box::new(SocketCoordEndpoint { outbox, coord_rx, streams, readers, writers })
    }
}

struct SocketWorkerEndpoint {
    /// Write half; the worker thread is the sole writer on it.
    stream: Duplex,
    inbox: Receiver<WorkerMsg>,
    reader: Option<JoinHandle<()>>,
}

impl SocketWorkerEndpoint {
    /// Wrap a connected stream: spawn the reader thread that decodes
    /// incoming frames into an in-memory inbox (so blocking / polling
    /// receives cost the same as in channel mode). Also serves
    /// `dicodile worker --listen` connections.
    fn over(stream: Duplex) -> Self {
        let (tx, rx) = mpsc::channel::<WorkerMsg>();
        let mut rh = stream.try_clone().expect("socket transport: clone read half");
        let reader = std::thread::spawn(move || loop {
            match read_frame(&mut rh) {
                Ok(Some(payload)) => match decode_frame(&payload) {
                    Ok(WireFrame::Worker(m)) => {
                        if tx.send(m).is_err() {
                            break;
                        }
                    }
                    // Only coordinator→worker frames may arrive here.
                    Ok(_) | Err(_) => break,
                },
                Ok(None) | Err(_) => break,
            }
        });
        SocketWorkerEndpoint { stream, inbox: rx, reader: Some(reader) }
    }
}

impl WorkerEndpoint for SocketWorkerEndpoint {
    fn recv(&mut self) -> Result<WorkerMsg, RecvError> {
        self.inbox.recv().map_err(|_| RecvError::Closed)
    }

    fn try_recv(&mut self) -> Result<WorkerMsg, RecvError> {
        self.inbox.try_recv().map_err(|e| match e {
            TryRecvError::Empty => RecvError::Empty,
            TryRecvError::Disconnected => RecvError::Closed,
        })
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<WorkerMsg, RecvError> {
        self.inbox.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => RecvError::Timeout,
            RecvTimeoutError::Disconnected => RecvError::Closed,
        })
    }

    fn send_update(&mut self, to: usize, msg: UpdateMsg) {
        let _ = write_frame(&mut self.stream, &encode_fwd_frame(to, &msg));
    }

    fn send_coord(&mut self, msg: CoordMsg) {
        let _ = write_frame(&mut self.stream, &encode_coord_frame(&msg));
    }
}

impl Drop for SocketWorkerEndpoint {
    fn drop(&mut self) {
        // Tear the socket down so (a) our reader thread unblocks and
        // (b) the hub sees EOF and retires this link.
        self.stream.shutdown_both();
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
    }
}

struct SocketCoordEndpoint {
    outbox: Vec<Sender<Vec<u8>>>,
    coord_rx: Receiver<CoordMsg>,
    streams: Vec<Duplex>,
    readers: Vec<JoinHandle<()>>,
    writers: Vec<JoinHandle<()>>,
}

impl CoordEndpoint for SocketCoordEndpoint {
    fn send(&mut self, rank: usize, msg: WorkerMsg) {
        let _ = self.outbox[rank].send(encode_worker_frame(&msg));
    }

    fn broadcast(&mut self, n: usize, msg: WorkerMsg) {
        // Encode once, share the bytes: a `SetDict`/`SetProblem`
        // broadcast serializes the `DictUpdate` a single time and every
        // rank's writer thread ships the same frame — the same
        // pre-encoded-frame discipline the hub already applies to
        // routed worker→worker updates.
        let frame = encode_worker_frame(&msg);
        for tx in &self.outbox[..n] {
            let _ = tx.send(frame.clone());
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<CoordMsg, RecvError> {
        self.coord_rx.recv_timeout(timeout).map_err(|e| match e {
            RecvTimeoutError::Timeout => RecvError::Timeout,
            RecvTimeoutError::Disconnected => RecvError::Closed,
        })
    }
}

impl Drop for SocketCoordEndpoint {
    fn drop(&mut self) {
        // In the orderly path workers have already been joined, so the
        // queued frames are long delivered; in failure paths this cuts
        // every link so no helper thread can outlive the pool. Order
        // matters: drop our outbox senders, sever the sockets (unblocks
        // the readers), join readers (their exit drops the last outbox
        // clones), then the writers can be joined.
        self.outbox.clear();
        for s in &self.streams {
            s.shutdown_both();
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        for h in self.writers.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Served workers: `dicodile worker --listen <addr>`
// ---------------------------------------------------------------------------

/// `PartitionKind` wire code (see [`BootstrapMsg::partition`]).
pub fn partition_code(k: PartitionKind) -> u8 {
    match k {
        PartitionKind::Line => 0,
        PartitionKind::Grid => 1,
    }
}

/// `Strategy` wire code (see [`BootstrapMsg::strategy`]).
pub fn strategy_code(s: Strategy) -> u8 {
    match s {
        Strategy::Greedy => 0,
        Strategy::Randomized => 1,
        Strategy::LocallyGreedy => 2,
    }
}

/// `SelectMode` wire code (see [`BootstrapMsg::select`]).
pub fn select_code(m: SelectMode) -> u8 {
    match m {
        SelectMode::Rescan => 0,
        SelectMode::Incremental => 1,
    }
}

fn partition_from_code(c: u8) -> Result<PartitionKind, String> {
    match c {
        0 => Ok(PartitionKind::Line),
        1 => Ok(PartitionKind::Grid),
        other => Err(format!("bad partition code {other}")),
    }
}

fn strategy_from_code(c: u8) -> Result<Strategy, String> {
    match c {
        0 => Ok(Strategy::Greedy),
        1 => Ok(Strategy::Randomized),
        2 => Ok(Strategy::LocallyGreedy),
        other => Err(format!("bad strategy code {other}")),
    }
}

fn select_from_code(c: u8) -> Result<SelectMode, String> {
    match c {
        0 => Ok(SelectMode::Rescan),
        1 => Ok(SelectMode::Incremental),
        other => Err(format!("bad select code {other}")),
    }
}

/// Build the handshake a coordinator sends to a served worker.
pub fn bootstrap_for(
    rank: usize,
    problem: &CscProblem,
    cfg: &DicodConfig,
    z0: Option<&NdTensor>,
) -> BootstrapMsg {
    BootstrapMsg {
        rank,
        n_workers: cfg.n_workers,
        partition: partition_code(cfg.partition),
        strategy: strategy_code(cfg.strategy),
        select: select_code(cfg.select),
        soft_lock: cfg.soft_lock,
        tol: cfg.tol,
        max_updates: cfg.max_updates as u64,
        divergence_guard: cfg.divergence_guard,
        seed: cfg.seed,
        timeout: cfg.timeout,
        inbox_every: cfg.inbox_every as u64,
        x: (*problem.x).clone(),
        d: problem.d.clone(),
        lambda: problem.lambda,
        z0: z0.cloned(),
    }
}

fn config_from_bootstrap(b: &BootstrapMsg) -> Result<DicodConfig, String> {
    Ok(DicodConfig {
        n_workers: b.n_workers,
        partition: partition_from_code(b.partition)?,
        strategy: strategy_from_code(b.strategy)?,
        select: select_from_code(b.select)?,
        soft_lock: b.soft_lock,
        tol: b.tol,
        max_updates: b.max_updates as usize,
        divergence_guard: b.divergence_guard,
        seed: b.seed,
        timeout: b.timeout,
        inbox_every: b.inbox_every as usize,
        persistent: true,
        transport: TransportKind::Socket,
        // A served worker only ever runs solve phases it is told to
        // run; alternation scheduling lives with the coordinator.
        alternation: Alternation::Barrier,
    })
}

/// Run one worker over an established connection: read the `Bootstrap`
/// frame, rebuild the problem and grid locally, and enter the standard
/// worker loop until `Shutdown` (or disconnect). The spectra of the
/// rebuilt correlation engine are computed on this host — that is the
/// documented per-host cost of the wire `SetDict`/`Bootstrap` path.
fn serve(mut stream: Duplex) -> Result<(), String> {
    let payload = read_frame(&mut stream)
        .map_err(|e| format!("reading bootstrap: {e}"))?
        .ok_or("peer closed before bootstrap")?;
    let b = match decode_frame(&payload) {
        Ok(WireFrame::Bootstrap(b)) => b,
        Ok(_) => return Err("first frame must be a bootstrap".into()),
        Err(e) => return Err(format!("bad bootstrap frame: {e}")),
    };
    if b.rank >= b.n_workers {
        return Err(format!("rank {} out of range for {} workers", b.rank, b.n_workers));
    }
    let cfg = Arc::new(config_from_bootstrap(&b)?);
    let problem = Arc::new(CscProblem::new(b.x.clone(), b.d.clone(), b.lambda));
    let grid = Arc::new(WorkerGrid::new(
        &problem.z_spatial_dims(),
        problem.atom_dims(),
        cfg.n_workers,
        cfg.partition,
    ));
    if let Some(z0) = &b.z0 {
        if z0.dims() != problem.z_dims() {
            return Err("bootstrap z0 dims mismatch".into());
        }
    }
    let peers = grid.neighbor_links(b.rank);
    let ctx = PoolWorkerCtx {
        rank: b.rank,
        problem,
        grid,
        cfg,
        endpoint: Box::new(SocketWorkerEndpoint::over(stream)),
        peers,
        z0: b.z0.as_ref().map(|z| Arc::new(z.clone())),
    };
    run_pool_worker(ctx);
    Ok(())
}

/// Serve one worker over an accepted Unix-domain connection (test
/// harness entry; `serve_worker_listen` is the CLI path).
#[cfg(unix)]
pub fn serve_worker_unix(stream: std::os::unix::net::UnixStream) -> Result<(), String> {
    serve(Duplex::Unix(stream))
}

/// Serve one worker over an accepted TCP connection.
pub fn serve_worker_tcp(stream: std::net::TcpStream) -> Result<(), String> {
    let _ = stream.set_nodelay(true);
    serve(Duplex::Tcp(stream))
}

/// Bind `addr`, accept exactly one coordinator connection, and serve a
/// worker on it until shutdown. An `addr` containing `:` is a TCP
/// `host:port`; anything else is a Unix-domain socket path.
pub fn serve_worker_listen(addr: &str) -> Result<(), String> {
    if addr.contains(':') {
        let listener =
            std::net::TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let (stream, _) = listener.accept().map_err(|e| format!("accept on {addr}: {e}"))?;
        serve_worker_tcp(stream)
    } else {
        #[cfg(unix)]
        {
            // A stale socket file from a previous run would make bind
            // fail; replacing it is the conventional UDS server move.
            let _ = std::fs::remove_file(addr);
            let listener = std::os::unix::net::UnixListener::bind(addr)
                .map_err(|e| format!("bind {addr}: {e}"))?;
            let (stream, _) = listener.accept().map_err(|e| format!("accept on {addr}: {e}"))?;
            let r = serve(Duplex::Unix(stream));
            let _ = std::fs::remove_file(addr);
            r
        }
        #[cfg(not(unix))]
        {
            Err(format!("unix-domain path {addr:?} unsupported on this platform; use host:port"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parses() {
        assert_eq!("channel".parse::<TransportKind>().unwrap(), TransportKind::Channel);
        assert_eq!("socket".parse::<TransportKind>().unwrap(), TransportKind::Socket);
        assert!("smoke".parse::<TransportKind>().is_err());
        assert_eq!(TransportKind::Socket.name(), "socket");
    }

    #[test]
    fn frame_io_round_trips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut cur).unwrap().unwrap(), b"");
        assert!(read_frame(&mut cur).unwrap().is_none(), "clean EOF at frame boundary");
    }

    #[test]
    fn frame_io_rejects_partials_and_giants() {
        // EOF inside the header.
        let mut cur = std::io::Cursor::new(vec![5u8, 0]);
        assert!(read_frame(&mut cur).is_err());
        // EOF inside the payload.
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        buf.truncate(buf.len() - 2);
        let mut cur = std::io::Cursor::new(buf);
        assert!(read_frame(&mut cur).is_err());
        // Corrupt length prefix beyond the cap.
        let mut cur = std::io::Cursor::new(u32::MAX.to_le_bytes().to_vec());
        assert!(read_frame(&mut cur).is_err());
    }

    #[test]
    fn channel_endpoints_deliver_all_three_edges() {
        let mut t = ChannelTransport::new(2);
        let mut coord = t.take_coord_endpoint();
        let mut w0 = t.take_worker_endpoint(0);
        let mut w1 = t.take_worker_endpoint(1);
        drop(t);

        coord.send(0, WorkerMsg::Solve);
        assert!(matches!(w0.recv(), Ok(WorkerMsg::Solve)));

        let upd = UpdateMsg { from: 0, k: 1, u: vec![3], dz: 0.5 };
        w0.send_update(1, upd.clone());
        match w1.recv() {
            Ok(WorkerMsg::Update(got)) => assert_eq!(got, upd),
            other => panic!("expected update, got {other:?}"),
        }

        w1.send_coord(CoordMsg::DictSet { from: 1 });
        match coord.recv_timeout(Duration::from_secs(1)) {
            Ok(CoordMsg::DictSet { from }) => assert_eq!(from, 1),
            other => panic!("expected dictset, got {other:?}"),
        }

        // Reply edge disconnects when the last worker endpoint dies.
        drop(w0);
        drop(w1);
        assert!(matches!(
            coord.recv_timeout(Duration::from_millis(50)),
            Err(RecvError::Closed)
        ));
    }

    #[test]
    fn socket_endpoints_deliver_all_three_edges() {
        let mut t = SocketTransport::new(2);
        let mut coord = t.take_coord_endpoint();
        let mut w0 = t.take_worker_endpoint(0);
        let mut w1 = t.take_worker_endpoint(1);
        drop(t);

        coord.send(0, WorkerMsg::Solve);
        assert!(matches!(w0.recv(), Ok(WorkerMsg::Solve)));

        let upd = UpdateMsg { from: 0, k: 1, u: vec![-2, 7], dz: -0.25 };
        w0.send_update(1, upd.clone());
        match w1.recv() {
            Ok(WorkerMsg::Update(got)) => assert_eq!(got, upd),
            other => panic!("expected routed update, got {other:?}"),
        }

        w1.send_coord(CoordMsg::DictSet { from: 1 });
        match coord.recv_timeout(Duration::from_secs(5)) {
            Ok(CoordMsg::DictSet { from }) => assert_eq!(from, 1),
            other => panic!("expected dictset, got {other:?}"),
        }

        drop(w0);
        drop(w1);
        // Hub readers see EOF, reply senders drop, edge closes.
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        loop {
            match coord.recv_timeout(Duration::from_millis(20)) {
                Err(RecvError::Closed) => break,
                Err(RecvError::Timeout) if std::time::Instant::now() < deadline => continue,
                other => panic!("expected closed edge, got {other:?}"),
            }
        }
    }
}
