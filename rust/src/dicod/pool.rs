//! `WorkerPool` — the resident distributed runtime.
//!
//! The pool spawns the worker grid **once** and keeps it alive for the
//! whole CDL alternation (Algorithm 2). Workers own their activation
//! and beta windows across outer iterations; the pool drives them
//! through phases:
//!
//! ```text
//! spawn ─> [ Solve ─> ComputeStats ─> SetDict ]* ─> Gather ─> Shutdown
//! ```
//!
//! - `solve()` runs DiCoDiLe-Z warm-started from each worker's resident
//!   Z and supervises the counter-based termination protocol (the pool
//!   never touches beta or Z — all hot-path traffic is
//!   worker-to-worker).
//! - `compute_stats()` has every worker compute its φ^w/ψ^w partials
//!   (eq. 17) on its resident windows; only these O(K²(2L)^d) partials
//!   travel to the pool, never Z — removing the O(signal) round-trip
//!   per outer iteration that centralized CDL pays.
//! - `set_dict()` broadcasts the rebuilt problem (shared X, new D);
//!   workers re-bootstrap beta *warm* from the Z they already hold. On
//!   the channel transport the new engine's spectra cache is shared
//!   through the broadcast `Arc`, so dictionary spectra are regenerated
//!   once per broadcast; on the socket transport the broadcast crosses
//!   the wire as a [`DictUpdate`](crate::dicod::messages::DictUpdate)
//!   and each receiving *host* regenerates them once locally.
//! - `gather()` assembles the full Z — used exactly once, for the final
//!   result.
//!
//! Two alternation modes drive these phases from the CDL driver
//! ([`Alternation`](crate::dicod::config::Alternation)). *Barrier* (the
//! default) runs them strictly in sequence — bit-identical to the
//! historical trajectory. *Pipelined* fuses them with
//! [`solve_overlapped`](WorkerPool::solve_overlapped): `ComputeStats`
//! and `ResumeSolve` are broadcast back-to-back, so each worker ships
//! its φ/ψ partial and immediately resumes coordinate descent
//! *speculatively under the old dictionary* while the coordinator
//! thread reduces the partials and runs the dictionary PGD; the
//! accepted step then lands as a mid-solve `SetDict` — the ordinary
//! warm beta re-init, applied inside the live phase — and the phase is
//! supervised to convergence under the new dictionary. A worker's
//! idle/converged state only counts toward the stop decision after its
//! `DictSet` ack, so the Safra counter settlement is re-proved across
//! the mid-solve swap.
//!
//! All delivery goes through the pluggable
//! [`Transport`](crate::dicod::transport::Transport) seam
//! (`DicodConfig::transport`): the pool holds only a [`CoordEndpoint`],
//! the workers only their
//! [`WorkerEndpoint`](crate::dicod::transport::WorkerEndpoint)s, and
//! the phase protocol — including the Safra counter settlement — is
//! byte-for-byte the same over in-process channels and loopback
//! sockets.
//!
//! `solve_distributed` remains available as a thin one-shot wrapper
//! over a temporary pool, so single-solve callers and the paper-figure
//! benches are unchanged.

use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::csc::problem::CscProblem;
use crate::dicod::config::DicodConfig;
use crate::dicod::messages::{CoordMsg, SetDictMsg, SetProblemMsg, WorkerMsg, WorkerStats};
use crate::dicod::partition::WorkerGrid;
use crate::dicod::transport::{make_transport, CoordEndpoint, RecvError, TransportKind};
use crate::dicod::worker::{run_pool_worker, PoolWorkerCtx};
use crate::dict::phi_psi::DictStats;
use crate::tensor::NdTensor;

/// Outcome of one solve phase.
#[derive(Clone, Debug)]
pub struct PoolSolve {
    pub converged: bool,
    pub diverged: bool,
    /// Wall-clock seconds of the phase.
    pub runtime: f64,
}

/// Outcome of one pipelined leg
/// (see [`solve_overlapped`](WorkerPool::solve_overlapped)).
pub struct OverlappedLeg<T> {
    /// The reduced φ/ψ sufficient statistics (eq. 17).
    pub stats: DictStats,
    /// Total activation nonzeros at reduction time.
    pub z_nnz: usize,
    /// Whatever the `update` closure carried out (cost, the new
    /// dictionary, convergence bookkeeping).
    pub carry: T,
    /// Outcome of the resumed phase: converged under the new
    /// dictionary, or retired by `Stop` when `update` returned `None`.
    pub phase: PoolSolve,
    /// Seconds the grid spent without a live solve phase — from entry
    /// (the caller invokes this right after the previous phase
    /// settles) to the `ResumeSolve` broadcast. The pipelined analogue
    /// of the barrier mode's full reduce + PGD + `SetDict` wait;
    /// overlapping pushes it to ~0.
    pub dict_wait_s: f64,
}

/// How a supervision loop enters a live solve phase (see
/// [`WorkerPool::solve`], [`WorkerPool::set_dict_midsolve`],
/// [`WorkerPool::stop_resumed_solve`]).
struct Supervise {
    /// Require a `DictSet` ack per worker before its idle/converged
    /// state counts toward the stop decision (mid-solve swap: tracked
    /// state predating a worker's ack reflects the old dictionary).
    dict_acks: bool,
    /// Broadcast `Stop` immediately (retiring a speculative phase).
    stop_now: bool,
}

/// End-of-run summary of a pool (for `CdlResult` provenance and the
/// residency assertions in the tests).
#[derive(Clone, Debug)]
pub struct PoolReport {
    pub n_workers: usize,
    /// Worker threads spawned over the pool's lifetime (exactly
    /// `n_workers` — residency means no respawns).
    pub workers_spawned: usize,
    /// Which transport carried the grid's messages for this run.
    pub transport: TransportKind,
    /// Aggregated cumulative worker counters.
    pub stats: WorkerStats,
    pub per_worker: Vec<WorkerStats>,
    /// Bytes held by the problem's `CorrEngine` spectrum cache at
    /// report time (halved under the default rfft layout relative to
    /// packed complex spectra).
    pub spectra_bytes: usize,
    /// Set by the owning session when this pool was shut down by the
    /// LRU residency policy (`max_resident_pools`); always `false` on a
    /// report taken from a live pool.
    pub evicted: bool,
}

/// Resident worker-pool session over one `CscProblem` domain.
pub struct WorkerPool {
    grid: Arc<WorkerGrid>,
    cfg: Arc<DicodConfig>,
    problem: Arc<CscProblem>,
    coord: Box<dyn CoordEndpoint>,
    transport_kind: TransportKind,
    handles: Vec<JoinHandle<()>>,
    per_worker: Vec<WorkerStats>,
    x_norm_sq: f64,
    workers_spawned: usize,
    down: bool,
    /// Recycled φ/ψ reduction buffers: `compute_stats` swaps them with
    /// a worker partial each outer iteration, so the steady state
    /// allocates no fresh accumulators pool-side.
    stats_acc: Option<(NdTensor, NdTensor)>,
}

impl WorkerPool {
    /// Spawn the worker grid for `problem` (optionally warm-started
    /// from a full-domain activation). Workers bootstrap their beta
    /// windows in parallel and then idle on their inboxes.
    pub fn spawn(problem: Arc<CscProblem>, cfg: &DicodConfig, z0: Option<&NdTensor>) -> WorkerPool {
        let zsp = problem.z_spatial_dims();
        let grid = Arc::new(WorkerGrid::new(
            &zsp,
            problem.atom_dims(),
            cfg.n_workers,
            cfg.partition,
        ));
        let w_tot = grid.n_workers();
        let cfg = Arc::new(cfg.clone());

        if let Some(z0) = z0 {
            assert_eq!(
                z0.dims(),
                &problem.z_dims()[..],
                "warm-start Z dims must match the problem's activation dims"
            );
        }
        let z0 = z0.map(|z| Arc::new(z.clone()));

        // Build the selected transport and hand each side its endpoint.
        // The transport object is dropped once the grid is up; for the
        // channel transport that drop severs the master reply sender,
        // so a dead grid disconnects the coordinator endpoint.
        let mut transport = make_transport(cfg.transport, w_tot);
        let transport_kind = transport.kind();
        let coord = transport.take_coord_endpoint();

        let mut handles = Vec::with_capacity(w_tot);
        for rank in 0..w_tot {
            let ctx = PoolWorkerCtx {
                rank,
                problem: problem.clone(),
                grid: grid.clone(),
                cfg: cfg.clone(),
                endpoint: transport.take_worker_endpoint(rank),
                peers: grid.neighbor_links(rank),
                z0: z0.clone(),
            };
            handles.push(std::thread::spawn(move || run_pool_worker(ctx)));
        }
        drop(transport);

        let x_norm_sq = problem.x.norm_sq();
        WorkerPool {
            grid,
            cfg,
            problem,
            coord,
            transport_kind,
            handles,
            per_worker: vec![WorkerStats::default(); w_tot],
            x_norm_sq,
            workers_spawned: w_tot,
            down: false,
            stats_acc: None,
        }
    }

    /// Number of workers in the grid (may be below the requested count
    /// when the domain cannot be split that far).
    pub fn n_workers(&self) -> usize {
        self.grid.n_workers()
    }

    /// Worker threads spawned over the pool's lifetime.
    pub fn workers_spawned(&self) -> usize {
        self.workers_spawned
    }

    /// The problem currently broadcast to the workers.
    pub fn problem(&self) -> &Arc<CscProblem> {
        &self.problem
    }

    /// Latest per-worker counter snapshots.
    pub fn per_worker(&self) -> &[WorkerStats] {
        &self.per_worker
    }

    /// Merge of the latest per-worker counter snapshots.
    pub fn aggregate_stats(&self) -> WorkerStats {
        let mut agg = WorkerStats::default();
        for s in &self.per_worker {
            agg.merge(s);
        }
        agg
    }

    /// Which transport carries this pool's messages.
    pub fn transport(&self) -> TransportKind {
        self.transport_kind
    }

    /// The solver configuration this pool was spawned with (the CDL
    /// driver reads `alternation` from here — the pool's config is
    /// authoritative for the grid it spawned).
    pub fn config(&self) -> &DicodConfig {
        &self.cfg
    }

    /// End-of-run summary.
    pub fn report(&self) -> PoolReport {
        PoolReport {
            n_workers: self.n_workers(),
            workers_spawned: self.workers_spawned,
            transport: self.transport_kind,
            stats: self.aggregate_stats(),
            per_worker: self.per_worker.clone(),
            spectra_bytes: self.problem.corr.spectra_bytes(),
            evicted: false,
        }
    }

    fn broadcast(&mut self, msg: WorkerMsg) {
        // Route through the endpoint's broadcast so the socket
        // transport can encode the frame once and share the bytes
        // across ranks (a `SetDict` payload is the whole dictionary).
        self.coord.broadcast(self.grid.n_workers(), msg);
    }

    /// Drain coordinator messages until every worker has produced this
    /// phase's reply. `visit` returns `Some(rank)` when a message is
    /// the awaited reply for `rank` (duplicates counted once); other
    /// messages are ignored.
    ///
    /// Shortfall policy: panic. A missing reply means a worker thread
    /// died or wedged past `timeout`; continuing would silently corrupt
    /// the resident state (e.g. a gathered Z with a zeroed cell), so
    /// the run fails loudly instead.
    fn await_replies(
        coord: &mut dyn CoordEndpoint,
        w_tot: usize,
        timeout: f64,
        phase: &str,
        mut visit: impl FnMut(CoordMsg) -> Option<usize>,
    ) {
        let deadline = Instant::now() + Duration::from_secs_f64(timeout);
        let mut seen = vec![false; w_tot];
        let mut got = 0usize;
        while got < w_tot {
            let msg = coord.recv_timeout(Duration::from_millis(20));
            match msg {
                Ok(m) => {
                    if let Some(rank) = visit(m) {
                        if !seen[rank] {
                            seen[rank] = true;
                            got += 1;
                        }
                    }
                }
                Err(RecvError::Timeout) => {}
                Err(_) => panic!(
                    "worker pool: grid disconnected during {phase} ({got}/{w_tot} replies)"
                ),
            }
            if got < w_tot && Instant::now() > deadline {
                panic!("worker pool: {phase} timed out with {got}/{w_tot} replies");
            }
        }
    }

    /// Run one solve phase: DiCoDiLe-Z from the workers' resident Z
    /// windows, Safra-style termination supervision, `Stop` broadcast
    /// on global convergence/divergence/timeout, then one `SolveDone`
    /// ack per worker.
    pub fn solve(&mut self) -> PoolSolve {
        let start = Instant::now();
        self.broadcast(WorkerMsg::Solve);
        self.supervise_solve(start, Supervise { dict_acks: false, stop_now: false })
    }

    /// One pipelined alternation leg, fused (see the module docs):
    /// broadcast `ComputeStats` + `ResumeSolve` back-to-back — each
    /// worker ships its φ/ψ partial and immediately resumes coordinate
    /// descent speculatively under the current dictionary — reduce the
    /// partials, run `update` on this thread while the grid works,
    /// then land the returned problem mid-solve and supervise the
    /// resumed phase to convergence under it. When `update` returns
    /// `None` the phase is retired with `Stop` instead (final
    /// iteration, or the driver's dead-atom fallback to barrier
    /// semantics) — the extra speculative updates are ordinary warm
    /// progress under the unchanged dictionary, so the resident Z only
    /// improves before a subsequent `Gather`.
    pub fn solve_overlapped<T>(
        &mut self,
        update: impl FnOnce(&DictStats, usize) -> (Option<Arc<CscProblem>>, T),
    ) -> OverlappedLeg<T> {
        let (stats, z_nnz, dict_wait_s) = self.compute_stats_overlapped();
        let (next, carry) = update(&stats, z_nnz);
        let phase = match next {
            Some(problem) => self.set_dict_midsolve(problem),
            None => self.stop_resumed_solve(),
        };
        OverlappedLeg { stats, z_nnz, carry, phase, dict_wait_s }
    }

    /// First half of a pipelined leg, split out for drivers that must
    /// reduce partials from *several* pools before they can build the
    /// new dictionary (batch CDL): broadcast `ComputeStats` +
    /// `ResumeSolve` back-to-back and collect this pool's φ/ψ partials
    /// while its grid resumes coordinate descent speculatively under
    /// the current dictionary. Returns `(stats, z_nnz, dict_wait_s)`.
    /// The caller owns a live (resumed) solve phase afterwards and must
    /// finish the leg with
    /// [`set_dict_midsolve`](WorkerPool::set_dict_midsolve) or
    /// [`stop_resumed_solve`](WorkerPool::stop_resumed_solve).
    pub fn compute_stats_overlapped(&mut self) -> (DictStats, usize, f64) {
        let t0 = Instant::now();
        // FIFO inboxes order the pair: partials first, then re-entry.
        self.broadcast(WorkerMsg::ComputeStats);
        self.broadcast(WorkerMsg::ResumeSolve);
        let dict_wait_s = t0.elapsed().as_secs_f64();
        let (stats, z_nnz) = self.collect_stats();
        (stats, z_nnz, dict_wait_s)
    }

    /// Retire a speculative (resumed) solve phase without landing a
    /// new dictionary: broadcast `Stop` and collect the `SolveDone`
    /// acks.
    pub fn stop_resumed_solve(&mut self) -> PoolSolve {
        let start = Instant::now();
        self.supervise_solve(start, Supervise { dict_acks: false, stop_now: true })
    }

    /// Land a dictionary swap on a *running* (resumed) solve phase and
    /// supervise it to convergence under the new dictionary —
    /// [`set_dict`](WorkerPool::set_dict) + [`solve`](WorkerPool::solve)
    /// fused into the live phase. Each worker applies the broadcast as
    /// its usual warm beta re-init without leaving the solve loop;
    /// supervision counts a worker's idle/converged state only after
    /// its `DictSet` ack (per-worker FIFO order guarantees every
    /// post-ack status reflects the new dictionary), so the Safra
    /// settlement is re-proved across the swap.
    pub fn set_dict_midsolve(&mut self, problem: Arc<CscProblem>) -> PoolSolve {
        self.assert_dict_swap_geometry(&problem);
        let start = Instant::now();
        self.problem = problem.clone();
        self.broadcast(WorkerMsg::SetDict(SetDictMsg::Shared(problem)));
        self.supervise_solve(start, Supervise { dict_acks: true, stop_now: false })
    }

    /// Supervise a live solve phase to completion: Safra-style
    /// termination tracking, one `Stop` broadcast, one `SolveDone` ack
    /// per worker. Shared by [`solve`](WorkerPool::solve) and the
    /// pipelined legs.
    fn supervise_solve(&mut self, start: Instant, mode: Supervise) -> PoolSolve {
        let w_tot = self.n_workers();
        let mut idle = vec![false; w_tot];
        let mut converged = vec![false; w_tot];
        let mut sent = vec![0u64; w_tot];
        let mut received = vec![0u64; w_tot];
        let mut acked = vec![!mode.dict_acks; w_tot];
        let mut any_diverged = false;
        let mut stop_sent = false;
        let mut acks = 0usize;
        let deadline = Instant::now() + Duration::from_secs_f64(self.cfg.timeout);
        // Workers ack Stop promptly; the hard deadline only guards
        // against a wedged thread so a bad run fails loudly instead of
        // hanging (same shortfall policy as `await_replies`).
        let hard_deadline = deadline + Duration::from_secs_f64(self.cfg.timeout);
        if mode.stop_now {
            stop_sent = true;
            self.broadcast(WorkerMsg::Stop);
        }

        while acks < w_tot {
            let msg = self.coord.recv_timeout(Duration::from_millis(20));
            match msg {
                Ok(CoordMsg::Status(s)) => {
                    idle[s.from] = s.idle;
                    converged[s.from] = s.converged;
                    sent[s.from] = s.sent;
                    received[s.from] = s.received;
                    if s.diverged {
                        any_diverged = true;
                    }
                    let all_acked = acked.iter().all(|&b| b);
                    let all_idle = idle.iter().all(|&b| b);
                    let balanced =
                        sent.iter().sum::<u64>() == received.iter().sum::<u64>();
                    if !stop_sent && (any_diverged || (all_acked && all_idle && balanced)) {
                        stop_sent = true;
                        self.broadcast(WorkerMsg::Stop);
                    }
                }
                Ok(CoordMsg::DictSet { from }) => {
                    // Mid-solve swap ack: whatever was tracked for this
                    // worker predates the new dictionary — reset it so
                    // convergence is re-proved post-swap (the worker
                    // sends a fresh status right after this ack, or
                    // keeps solving and reports when it pauses).
                    acked[from] = true;
                    idle[from] = false;
                    converged[from] = false;
                }
                Ok(CoordMsg::SolveDone(d)) => {
                    self.per_worker[d.from] = d.stats;
                    acks += 1;
                }
                Ok(_) => {}
                Err(RecvError::Timeout) => {}
                Err(_) => panic!(
                    "worker pool: grid disconnected during solve ({acks}/{w_tot} acks)"
                ),
            }
            if !stop_sent && Instant::now() > deadline {
                stop_sent = true;
                self.broadcast(WorkerMsg::Stop);
            }
            if acks < w_tot && Instant::now() > hard_deadline {
                panic!("worker pool: solve timed out with {acks}/{w_tot} acks after Stop");
            }
        }

        PoolSolve {
            converged: converged.iter().all(|&b| b) && !any_diverged,
            diverged: any_diverged,
            runtime: start.elapsed().as_secs_f64(),
        }
    }

    /// Map-reduce the dictionary-update sufficient statistics from the
    /// workers' resident windows (eq. 17). Returns the reduced stats
    /// and the total activation nonzero count. Full Z never travels.
    pub fn compute_stats(&mut self) -> (DictStats, usize) {
        self.broadcast(WorkerMsg::ComputeStats);
        self.collect_stats()
    }

    /// Collect and reduce the φ/ψ partials after a `ComputeStats`
    /// broadcast. Interleaved `Status` traffic is ignored, so this is
    /// safe while a resumed solve phase runs (pipelined alternation) —
    /// statuses are cumulative and every worker re-reports after the
    /// mid-solve `SetDict`, so none of the dropped ones are load-
    /// bearing.
    fn collect_stats(&mut self) -> (DictStats, usize) {
        let w_tot = self.n_workers();
        let mut parts: Vec<Option<(NdTensor, NdTensor, f64, usize)>> = vec![None; w_tot];
        let timeout = self.cfg.timeout;
        Self::await_replies(self.coord.as_mut(), w_tot, timeout, "compute_stats", |m| {
            match m {
                CoordMsg::Stats(s) => {
                    let from = s.from;
                    parts[from] = Some((s.phi, s.psi, s.z_l1, s.z_nnz));
                    Some(from)
                }
                _ => None,
            }
        });
        // Reduce in rank order so the summation is deterministic.
        // (await_replies guarantees every slot is filled.)
        let mut it = parts
            .into_iter()
            .map(|p| p.expect("every worker reports a stats partial"));
        let (phi0, psi0, mut z_l1, mut z_nnz) = it.next().unwrap();
        // Accumulate into the recycled reduction buffers when available
        // (rank 0's partial becomes the next iteration's buffer, so the
        // steady state allocates nothing pool-side). Seeding by copy
        // keeps the reduction bitwise identical to accumulating into
        // the rank-0 partial directly.
        let (mut phi, mut psi) = match self.stats_acc.take() {
            Some((mut a, mut b)) if a.dims() == phi0.dims() && b.dims() == psi0.dims() => {
                a.data_mut().copy_from_slice(phi0.data());
                b.data_mut().copy_from_slice(psi0.data());
                self.stats_acc = Some((phi0, psi0));
                (a, b)
            }
            _ => (phi0, psi0),
        };
        for (p2, s2, l1, nnz) in it {
            phi.add_assign(&p2);
            psi.add_assign(&s2);
            z_l1 += l1;
            z_nnz += nnz;
            if self.stats_acc.is_none() {
                // First reduction (or a geometry change): keep one
                // worker partial as the recycled buffer pair.
                self.stats_acc = Some((p2, s2));
            }
        }
        (DictStats { phi, psi, x_norm_sq: self.x_norm_sq, z_l1 }, z_nnz)
    }

    /// Broadcast a rebuilt problem (same shared X, new dictionary).
    /// Workers re-bootstrap beta warm from their resident Z; the call
    /// returns once every worker has acknowledged the swap.
    pub fn set_dict(&mut self, problem: Arc<CscProblem>) {
        self.assert_dict_swap_geometry(&problem);
        let w_tot = self.n_workers();
        self.problem = problem.clone();
        // The coordinator always broadcasts the `Shared` form; the
        // socket transport flattens it to a wire `DictUpdate` at the
        // serialization seam (spectra then regenerate once per
        // receiving host — see the messages module docs).
        self.broadcast(WorkerMsg::SetDict(SetDictMsg::Shared(problem)));
        let timeout = self.cfg.timeout;
        Self::await_replies(self.coord.as_mut(), w_tot, timeout, "set_dict", |m| match m {
            CoordMsg::DictSet { from } => Some(from),
            _ => None,
        });
    }

    /// A dictionary swap must preserve the whole problem geometry (the
    /// workers' resident windows were sized from it) and reuse the
    /// *same shared* X: compute_stats completes the objective with the
    /// x_norm_sq cached at spawn, and the workers' windows slice X by
    /// identity.
    fn assert_dict_swap_geometry(&self, problem: &Arc<CscProblem>) {
        assert_eq!(
            problem.z_spatial_dims(),
            self.problem.z_spatial_dims(),
            "dictionary swap must preserve the activation domain"
        );
        assert_eq!(
            problem.n_atoms(),
            self.problem.n_atoms(),
            "dictionary swap must preserve the atom count"
        );
        assert_eq!(
            problem.atom_dims(),
            self.problem.atom_dims(),
            "dictionary swap must preserve the atom dims"
        );
        assert!(
            Arc::ptr_eq(&problem.x, &self.problem.x),
            "dictionary swap must reuse the pool's shared observation Arc"
        );
    }

    /// Broadcast a whole new problem — observation *and* dictionary —
    /// on an unchanged geometry, optionally with a full-domain warm
    /// start. This is the streaming-chunk swap: unlike
    /// [`set_dict`](WorkerPool::set_dict) the observation may be a
    /// different tensor (each chunk is a fresh signal window), so the
    /// cached `x_norm_sq` is refreshed and the workers reset their
    /// resident Z (to `z0` when given) and re-bootstrap beta. Geometry
    /// (activation domain, atom count/dims) must match the spawn-time
    /// problem: the worker windows are not re-partitioned.
    pub fn set_problem(&mut self, problem: Arc<CscProblem>, z0: Option<&NdTensor>) {
        assert_eq!(
            problem.z_spatial_dims(),
            self.problem.z_spatial_dims(),
            "problem swap must preserve the activation domain"
        );
        assert_eq!(
            problem.n_atoms(),
            self.problem.n_atoms(),
            "problem swap must preserve the atom count"
        );
        assert_eq!(
            problem.atom_dims(),
            self.problem.atom_dims(),
            "problem swap must preserve the atom dims"
        );
        if let Some(z0) = z0 {
            assert_eq!(
                z0.dims(),
                &problem.z_dims()[..],
                "warm-start Z dims must match the problem's activation dims"
            );
        }
        let z0 = z0.map(|z| Arc::new(z.clone()));
        let w_tot = self.n_workers();
        self.problem = problem.clone();
        self.x_norm_sq = problem.x.norm_sq();
        self.broadcast(WorkerMsg::SetProblem(SetProblemMsg::Shared { problem, z0 }));
        let timeout = self.cfg.timeout;
        Self::await_replies(self.coord.as_mut(), w_tot, timeout, "set_problem", |m| match m {
            CoordMsg::ProblemSet { from } => Some(from),
            _ => None,
        });
    }

    /// Assemble the full activation tensor from the workers' cells.
    /// This is the only point where Z is centralized — call it once,
    /// for the final result.
    pub fn gather(&mut self) -> NdTensor {
        let w_tot = self.n_workers();
        self.broadcast(WorkerMsg::Gather);
        let mut done: Vec<Option<Vec<f64>>> = vec![None; w_tot];
        let timeout = self.cfg.timeout;
        let per_worker = &mut self.per_worker;
        Self::await_replies(self.coord.as_mut(), w_tot, timeout, "gather", |m| match m {
            CoordMsg::Done(d) => {
                let from = d.from;
                per_worker[from] = d.stats;
                done[from] = Some(d.z_cell);
                Some(from)
            }
            _ => None,
        });

        let problem = &self.problem;
        let zsp = problem.z_spatial_dims();
        let k_tot = problem.n_atoms();
        let zstr = crate::tensor::shape::strides_of(&zsp);
        let sp: usize = zsp.iter().product();
        let mut z = NdTensor::zeros(&problem.z_dims());
        for (rank, slot) in done.iter().enumerate() {
            let cell_z = slot.as_ref().expect("await_replies fills every cell");
            let cell = self.grid.cell(rank);
            let cell_sp = cell.size();
            for k in 0..k_tot {
                for (i, u) in cell.iter().enumerate() {
                    let goff: usize =
                        u.iter().zip(&zstr).map(|(x, s)| *x as usize * s).sum();
                    z.data_mut()[k * sp + goff] = cell_z[k * cell_sp + i];
                }
            }
        }
        z
    }

    /// Tell the workers to exit and detach their threads without
    /// joining. For pools whose phase state is unknown (e.g. a
    /// supervision panic poisoned the owning session lock): a wedged
    /// worker never reads its inbox, so joining could hang — the exit
    /// message is best-effort and the handles are dropped. Idempotent
    /// with [`shutdown`](WorkerPool::shutdown).
    pub(crate) fn abandon(&mut self) {
        if self.down {
            return;
        }
        self.down = true;
        self.broadcast(WorkerMsg::Shutdown);
        self.handles.clear();
    }

    /// Stop the workers and join their threads. Idempotent; also runs
    /// on `Drop`.
    pub fn shutdown(&mut self) {
        if self.down {
            return;
        }
        self.down = true;
        self.broadcast(WorkerMsg::Shutdown);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        if std::thread::panicking() {
            // Unwinding from a shortfall panic: the wedged worker that
            // caused it would never read its inbox, so joining here
            // would hang the process and defeat the fail-loudly policy.
            // Tell the grid to exit and detach the handles instead.
            self.down = true;
            self.broadcast(WorkerMsg::Shutdown);
            self.handles.clear();
            return;
        }
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csc::cd::{solve_cd, CdConfig};
    use crate::util::rng::Pcg64;

    fn gen_problem_1d(seed: u64, t: usize, k: usize, l: usize) -> CscProblem {
        let mut rng = Pcg64::seeded(seed);
        let d = NdTensor::from_vec(&[k, 1, l], {
            let mut v = rng.normal_vec(k * l);
            for atom in v.chunks_mut(l) {
                let n = atom.iter().map(|x| x * x).sum::<f64>().sqrt();
                for x in atom.iter_mut() {
                    *x /= n;
                }
            }
            v
        });
        let mut z = NdTensor::zeros(&[k, t - l + 1]);
        for v in z.data_mut().iter_mut() {
            if rng.bernoulli(0.03) {
                *v = rng.normal_ms(0.0, 5.0);
            }
        }
        let clean = crate::conv::reconstruct(&z, &d);
        let noise =
            NdTensor::from_vec(clean.dims(), rng.normal_vec(clean.len())).scale(0.1);
        CscProblem::with_lambda_frac(clean.add(&noise), d, 0.1)
    }

    #[test]
    fn pool_solves_and_gathers() {
        let p = gen_problem_1d(21, 140, 2, 6);
        let seq = solve_cd(&p, &CdConfig { tol: 1e-8, ..Default::default() });
        let cfg = DicodConfig { n_workers: 3, tol: 1e-8, ..Default::default() };
        let mut pool = WorkerPool::spawn(Arc::new(p.clone()), &cfg, None);
        let s = pool.solve();
        assert!(s.converged);
        let z = pool.gather();
        let (cd, cs) = (p.cost(&z), p.cost(&seq.z));
        assert!((cd - cs).abs() < 1e-6 * (1.0 + cs.abs()), "{cd} vs {cs}");
    }

    #[test]
    fn report_records_transport_and_socket_pool_solves() {
        let p = gen_problem_1d(26, 100, 2, 5);
        let mut gathered = Vec::new();
        for kind in [TransportKind::Channel, TransportKind::Socket] {
            let cfg = DicodConfig {
                n_workers: 2,
                tol: 1e-8,
                transport: kind,
                ..Default::default()
            };
            let mut pool = WorkerPool::spawn(Arc::new(p.clone()), &cfg, None);
            assert!(pool.solve().converged, "{} pool must converge", kind.name());
            gathered.push(pool.gather());
            assert_eq!(pool.report().transport, kind);
        }
        // Same protocol, same math: the wire may only change timing,
        // and this tiny problem converges to the same optimum.
        let (a, b) = (&gathered[0], &gathered[1]);
        assert!(
            (p.cost(a) - p.cost(b)).abs() < 1e-9 * (1.0 + p.cost(a).abs()),
            "channel and socket pools must reach the same optimum"
        );
    }

    #[test]
    fn repeated_solves_are_idempotent_at_optimum() {
        // A second solve phase from the resident (optimal) Z must do no
        // updates and still report convergence.
        let p = gen_problem_1d(22, 120, 2, 5);
        let cfg = DicodConfig { n_workers: 2, tol: 1e-8, ..Default::default() };
        let mut pool = WorkerPool::spawn(Arc::new(p.clone()), &cfg, None);
        assert!(pool.solve().converged);
        let updates_before = pool.aggregate_stats().updates;
        assert!(pool.solve().converged);
        let agg = pool.aggregate_stats();
        assert_eq!(agg.updates, updates_before, "warm resident restart must be a no-op");
        assert_eq!(agg.solves, 2 * pool.n_workers() as u64);
        assert_eq!(agg.beta_cold_inits, pool.n_workers() as u64);
    }

    #[test]
    fn pool_stats_partials_match_sequential_stats() {
        let p = gen_problem_1d(23, 130, 3, 6);
        let cfg = DicodConfig { n_workers: 4, tol: 1e-8, ..Default::default() };
        let mut pool = WorkerPool::spawn(Arc::new(p.clone()), &cfg, None);
        pool.solve();
        let (stats, nnz) = pool.compute_stats();
        let z = pool.gather();
        let want = crate::dict::phi_psi::compute_stats(&z, &p.x, p.atom_dims());
        assert!(stats.phi.allclose(&want.phi, 1e-9), "phi partial reduction mismatch");
        assert!(stats.psi.allclose(&want.psi, 1e-9), "psi partial reduction mismatch");
        assert!((stats.z_l1 - want.z_l1).abs() < 1e-9 * (1.0 + want.z_l1));
        assert_eq!(nnz, z.nnz());
    }

    #[test]
    fn set_problem_retargets_the_grid_at_a_new_observation() {
        // Two independent problems with identical geometry: solving the
        // second on a pool spawned for the first (via set_problem) must
        // land on the same optimum as a fresh sequential solve, and the
        // pool's x_norm_sq must follow the swap (compute_stats uses it).
        let p0 = gen_problem_1d(27, 120, 2, 5);
        let p1 = gen_problem_1d(28, 120, 2, 5);
        let cfg = DicodConfig { n_workers: 3, tol: 1e-8, ..Default::default() };
        let mut pool = WorkerPool::spawn(Arc::new(p0.clone()), &cfg, None);
        assert!(pool.solve().converged);

        pool.set_problem(Arc::new(p1.clone()), None);
        assert!(pool.solve().converged, "swapped-in problem must converge");
        let (stats, _) = pool.compute_stats();
        assert!(
            (stats.x_norm_sq - p1.x.norm_sq()).abs() < 1e-9 * (1.0 + p1.x.norm_sq()),
            "x_norm_sq must track the swapped observation"
        );
        let z = pool.gather();
        let seq = solve_cd(&p1, &CdConfig { tol: 1e-8, ..Default::default() });
        let (cd, cs) = (p1.cost(&z), p1.cost(&seq.z));
        assert!((cd - cs).abs() < 1e-6 * (1.0 + cs.abs()), "{cd} vs {cs}");
        // Residency held: no respawn, one cold init at spawn plus one
        // warm-or-cold re-bootstrap per worker at the swap.
        assert_eq!(pool.workers_spawned(), pool.n_workers());
        let agg = pool.aggregate_stats();
        assert_eq!(agg.beta_cold_inits, 2 * pool.n_workers() as u64);
    }

    #[test]
    fn set_problem_warm_start_is_loaded() {
        // Broadcasting the sequential optimum as z0 must leave the grid
        // already converged: the next solve does zero updates.
        let p = gen_problem_1d(29, 120, 2, 5);
        let seq = solve_cd(&p, &CdConfig { tol: 1e-8, ..Default::default() });
        let cfg = DicodConfig { n_workers: 2, tol: 1e-8, ..Default::default() };
        let mut pool = WorkerPool::spawn(Arc::new(p.clone()), &cfg, None);
        assert!(pool.solve().converged);
        let updates_before = pool.aggregate_stats().updates;
        pool.set_problem(Arc::new(p.clone()), Some(&seq.z));
        assert!(pool.solve().converged);
        let agg = pool.aggregate_stats();
        assert_eq!(
            agg.updates, updates_before,
            "solve from the broadcast optimum must be a no-op"
        );
        assert_eq!(agg.beta_warm_inits, pool.n_workers() as u64);
        let z = pool.gather();
        assert!(z.allclose(&seq.z, 1e-12), "gathered Z must be the warm start");
    }

    #[test]
    fn set_dict_resolves_under_new_dictionary() {
        let p0 = gen_problem_1d(24, 120, 2, 5);
        let mut rng = Pcg64::seeded(25);
        let d1 = NdTensor::from_vec(&[2, 1, 5], {
            let mut v = rng.normal_vec(10);
            for atom in v.chunks_mut(5) {
                let n = atom.iter().map(|x| x * x).sum::<f64>().sqrt();
                for x in atom.iter_mut() {
                    *x /= n;
                }
            }
            v
        });
        let mut p1 = p0.clone();
        p1.update_dict(d1);

        let cfg = DicodConfig { n_workers: 3, tol: 1e-8, ..Default::default() };
        let mut pool = WorkerPool::spawn(Arc::new(p0.clone()), &cfg, None);
        assert!(pool.solve().converged);
        pool.set_dict(Arc::new(p1.clone()));
        assert!(pool.solve().converged, "stale-Z restart under a new D must converge");
        let z = pool.gather();
        let seq = solve_cd(&p1, &CdConfig { tol: 1e-8, ..Default::default() });
        let (cd, cs) = (p1.cost(&z), p1.cost(&seq.z));
        assert!((cd - cs).abs() < 1e-6 * (1.0 + cs.abs()), "{cd} vs {cs}");
        let agg = pool.aggregate_stats();
        assert_eq!(agg.beta_warm_reinits, pool.n_workers() as u64);
        assert_eq!(agg.beta_cold_inits, pool.n_workers() as u64);
    }

    #[test]
    fn overlapped_leg_lands_dict_midsolve() {
        // One pipelined leg over a converged grid: partials ship, the
        // grid resumes speculatively under the old dictionary, and the
        // new dictionary lands as a mid-solve `SetDict` (one warm
        // re-init per worker, no phase desync). The resumed phase must
        // settle at the same optimum a sequential solve reaches under
        // the new dictionary.
        let p0 = gen_problem_1d(61, 120, 2, 5);
        let mut rng = Pcg64::seeded(62);
        let d1 = NdTensor::from_vec(&[2, 1, 5], {
            let mut v = rng.normal_vec(10);
            for atom in v.chunks_mut(5) {
                let n = atom.iter().map(|x| x * x).sum::<f64>().sqrt();
                for x in atom.iter_mut() {
                    *x /= n;
                }
            }
            v
        });
        let mut p1 = p0.clone();
        p1.update_dict(d1);

        let w = 3usize;
        let cfg = DicodConfig { n_workers: w, tol: 1e-8, ..Default::default() };
        let mut pool = WorkerPool::spawn(Arc::new(p0.clone()), &cfg, None);
        assert!(pool.solve().converged);
        let leg = pool.solve_overlapped(|stats, z_nnz| {
            // Partials come from the settled resident Z.
            assert!(z_nnz > 0, "converged grid must hold activations");
            assert!(stats.z_l1 > 0.0);
            (Some(Arc::new(p1.clone())), ())
        });
        assert!(leg.phase.converged, "resumed phase must re-converge after the swap");
        assert!(!leg.phase.diverged);
        assert!(leg.dict_wait_s >= 0.0);

        let agg = pool.aggregate_stats();
        // The mid-solve swap is the ordinary warm re-init, once per
        // worker, and `ResumeSolve` counts as a solve phase.
        assert_eq!(agg.beta_warm_reinits, w as u64);
        assert_eq!(agg.solves, 2 * w as u64);
        // Safra settlement across the mid-solve broadcast: every
        // worker-to-worker update was received.
        assert_eq!(agg.msgs_sent, agg.msgs_received);

        let z = pool.gather();
        let seq = solve_cd(&p1, &CdConfig { tol: 1e-8, ..Default::default() });
        let (cd, cs) = (p1.cost(&z), p1.cost(&seq.z));
        assert!((cd - cs).abs() < 1e-6 * (1.0 + cs.abs()), "{cd} vs {cs}");
    }

    #[test]
    fn overlapped_leg_retires_cleanly_without_a_dict() {
        // `None` from the update closure stops the speculative phase
        // immediately (converged/last-iteration path): the pool must be
        // reusable afterwards and the grid must not have desynced.
        let p = gen_problem_1d(63, 120, 2, 5);
        let cfg = DicodConfig { n_workers: 2, tol: 1e-8, ..Default::default() };
        let mut pool = WorkerPool::spawn(Arc::new(p.clone()), &cfg, None);
        assert!(pool.solve().converged);
        let nnz_before = pool.gather().nnz();
        let leg = pool.solve_overlapped(|_, z_nnz| {
            assert_eq!(z_nnz, nnz_before);
            (None, ())
        });
        assert!(!leg.phase.diverged);
        // No dictionary landed: no warm re-init, Z unchanged, and the
        // pool still answers phases.
        let agg = pool.aggregate_stats();
        assert_eq!(agg.beta_warm_reinits, 0);
        assert_eq!(pool.gather().nnz(), nnz_before);
        assert!(pool.solve().converged);
    }
}
