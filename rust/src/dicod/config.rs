//! Configuration for the distributed solver.

pub use crate::dicod::partition::PartitionKind;
pub use crate::dicod::transport::TransportKind;
use crate::csc::select::{SelectMode, Strategy};

/// Outer CDL alternation scheduling on a resident pool.
///
/// `Barrier` is the classical alternation: the whole grid idles while
/// the coordinator reduces the φ/ψ partials and runs the dictionary
/// PGD, then `SetDict` lands between solve phases. `Pipelined` resumes
/// coordinate descent *speculatively under the old dictionary* the
/// moment a worker has shipped its partial, and applies `SetDict`
/// mid-solve as the ordinary warm beta re-init — the dictionary step's
/// wall clock is hidden behind useful solver progress. Barrier stays
/// bit-identical to the historical trajectory; Pipelined is gated by
/// convergence invariants (surrogate cost monotone within `nu`, final
/// KKT residual no worse at equal `tol`) rather than bitwise parity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Alternation {
    /// Strict alternation: the grid waits for the dictionary update.
    Barrier,
    /// Speculative solve under the old dictionary while PGD runs;
    /// `SetDict` is broadcast mid-solve.
    Pipelined,
}

impl Alternation {
    /// Stable lowercase name (used in bench records and logs).
    pub fn name(self) -> &'static str {
        match self {
            Alternation::Barrier => "barrier",
            Alternation::Pipelined => "pipelined",
        }
    }

    /// Resolve the run-wide default from `DICODILE_ALTERNATION`
    /// (`barrier` | `pipelined`; unset or unrecognized falls back to
    /// `Barrier` with a once-per-process warning).
    pub fn from_env() -> Self {
        match std::env::var("DICODILE_ALTERNATION") {
            Ok(s) => s.parse().unwrap_or_else(|e: String| {
                static WARNED: std::sync::Once = std::sync::Once::new();
                WARNED.call_once(|| eprintln!("warning: {e}; using barrier alternation"));
                Alternation::Barrier
            }),
            Err(_) => Alternation::Barrier,
        }
    }
}

impl std::str::FromStr for Alternation {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "barrier" => Ok(Alternation::Barrier),
            "pipelined" => Ok(Alternation::Pipelined),
            other => Err(format!(
                "unknown DICODILE_ALTERNATION '{other}' (expected 'barrier' or 'pipelined')"
            )),
        }
    }
}

/// Configuration of a DiCoDiLe-Z / DICOD run.
#[derive(Clone, Debug)]
pub struct DicodConfig {
    /// Number of workers W.
    pub n_workers: usize,
    /// Domain split: line (DICOD) or grid (DiCoDiLe-Z).
    pub partition: PartitionKind,
    /// Local selection strategy: `LocallyGreedy` (DiCoDiLe-Z) or
    /// `Greedy` (DICOD). `Randomized` is also supported for ablations.
    pub strategy: Strategy,
    /// Incremental (cached dz_opt + segment champions, the default) vs
    /// full-rescan segment selection in the workers' hot loop; both
    /// select bit-identical coordinates. Defaults from the
    /// `DICODILE_SELECT` env toggle.
    pub select: SelectMode,
    /// Enable the asynchronous soft-lock mechanism (eq. 14). Disabling
    /// it reproduces the paper's Fig. 5 divergence demonstration.
    pub soft_lock: bool,
    /// Global stopping tolerance on `||dZ||_inf`.
    pub tol: f64,
    /// Per-run cap on total accepted updates (safety; split across
    /// workers).
    pub max_updates: usize,
    /// Abort and flag divergence if `||Z||_inf` exceeds this value
    /// (the paper stops when `||Z||_inf > 50 / max_k ||D_k||_inf`).
    pub divergence_guard: Option<f64>,
    /// RNG seed (randomized strategy, tie-breaking jitter).
    pub seed: u64,
    /// Wall-clock timeout in seconds (safety for the no-soft-lock mode).
    pub timeout: f64,
    /// Drain the inbox only every `n` local iterations (1 = every
    /// iteration). On this single-core testbed the OS serializes the
    /// workers, which makes their beta views artificially fresh; larger
    /// values emulate the network latency of the paper's MPI cluster so
    /// the Fig. 5 interference experiment has real asynchrony to bite on.
    pub inbox_every: usize,
    /// When this config backs a CDL run (`CscBackend::Distributed`),
    /// keep the worker pool resident across the outer alternation:
    /// workers are spawned once, Z/beta stay on the workers, φ/ψ are
    /// reduced from worker partials and full Z is gathered only at the
    /// end (Algorithm 2 as the paper runs it). `false` reverts to the
    /// teardown/respawn driver (one pool per outer iteration, warm-
    /// started). One-shot `solve_distributed` calls ignore this flag —
    /// they are a single solve phase by definition.
    pub persistent: bool,
    /// Message delivery for the worker grid: in-process channels (the
    /// default — zero-copy, shared spectra on `SetDict`) or
    /// length-prefixed binary frames over loopback sockets (the wire
    /// path a multi-process grid would use; every message crosses the
    /// serialization seam). Defaults from the `DICODILE_TRANSPORT` env
    /// toggle (`channel` | `socket`).
    pub transport: TransportKind,
    /// Outer-loop scheduling for persistent CDL runs: `Barrier` (the
    /// default — grid idles through the dictionary PGD, bit-identical
    /// to the historical trajectory) or `Pipelined` (workers keep
    /// iterating speculatively under the old dictionary while PGD
    /// runs; `SetDict` lands mid-solve as a warm beta re-init).
    /// Defaults from the `DICODILE_ALTERNATION` env toggle. Ignored by
    /// one-shot solves and the teardown/respawn driver — there is no
    /// resident grid to overlap with.
    pub alternation: Alternation,
}

impl Default for DicodConfig {
    fn default() -> Self {
        DicodConfig {
            n_workers: 4,
            partition: PartitionKind::Grid,
            strategy: Strategy::LocallyGreedy,
            select: SelectMode::from_env(),
            soft_lock: true,
            tol: 1e-6,
            max_updates: 10_000_000,
            divergence_guard: None,
            seed: 0,
            timeout: 600.0,
            inbox_every: 1,
            persistent: false,
            transport: TransportKind::from_env(),
            alternation: Alternation::from_env(),
        }
    }
}

impl DicodConfig {
    /// The paper's DiCoDiLe-Z configuration. Persistent by default:
    /// inside a CDL run the worker pool stays resident across outer
    /// iterations (the paper's decentralized Algorithm 2).
    pub fn dicodile(n_workers: usize) -> Self {
        DicodConfig { n_workers, persistent: true, ..Default::default() }
    }

    /// The DICOD baseline (Moreau et al. 2018): line split, greedy local
    /// selection, no soft-locks (1-D interference analysis instead).
    pub fn dicod(n_workers: usize) -> Self {
        DicodConfig {
            n_workers,
            partition: PartitionKind::Line,
            strategy: Strategy::Greedy,
            soft_lock: false,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets() {
        let a = DicodConfig::dicodile(9);
        assert_eq!(a.n_workers, 9);
        assert!(a.soft_lock);
        assert!(a.persistent, "dicodile defaults to the resident pool");
        assert_eq!(a.partition, PartitionKind::Grid);
        let b = DicodConfig::dicod(4);
        assert!(!b.soft_lock);
        assert!(!b.persistent);
        assert_eq!(b.partition, PartitionKind::Line);
        assert_eq!(b.strategy, Strategy::Greedy);
    }

    #[test]
    fn transport_defaults_to_channel() {
        // (Holds unless the suite itself runs under DICODILE_TRANSPORT.)
        if std::env::var("DICODILE_TRANSPORT").is_err() {
            assert_eq!(DicodConfig::default().transport, TransportKind::Channel);
        }
    }

    #[test]
    fn alternation_defaults_to_barrier() {
        // (Holds unless the suite itself runs under DICODILE_ALTERNATION.)
        if std::env::var("DICODILE_ALTERNATION").is_err() {
            assert_eq!(DicodConfig::default().alternation, Alternation::Barrier);
        }
        assert_eq!("pipelined".parse::<Alternation>(), Ok(Alternation::Pipelined));
        assert_eq!("Barrier".parse::<Alternation>(), Ok(Alternation::Barrier));
        assert!("eager".parse::<Alternation>().is_err());
        assert_eq!(Alternation::Pipelined.name(), "pipelined");
    }
}
