//! Projected gradient descent with Armijo backtracking for the
//! dictionary update (Algorithm 2, step 5).
//!
//! Minimizes the quadratic `F(Z, .)` over the product of unit l2 balls
//! `||D_k||_2 <= 1`, using only the sufficient statistics — so each
//! iteration is independent of the signal size.

use crate::dict::grad::{cost_from_stats, grad_from_stats};
use crate::dict::phi_psi::DictStats;
use crate::tensor::ops::project_l2_ball;
use crate::tensor::NdTensor;

/// PGD configuration.
#[derive(Clone, Debug)]
pub struct PgdConfig {
    pub max_iter: usize,
    /// Stop when the relative cost decrease falls below this.
    pub tol: f64,
    /// Armijo sufficient-decrease constant.
    pub c1: f64,
    /// Backtracking shrink factor.
    pub shrink: f64,
    /// Step growth after a successful iteration.
    pub grow: f64,
    /// Maximum backtracking steps per iteration.
    pub max_backtrack: usize,
}

impl Default for PgdConfig {
    fn default() -> Self {
        PgdConfig {
            max_iter: 50,
            tol: 1e-8,
            c1: 1e-4,
            shrink: 0.5,
            grow: 1.6,
            max_backtrack: 40,
        }
    }
}

/// PGD run result.
#[derive(Clone, Debug)]
pub struct PgdResult {
    pub d: NdTensor,
    pub cost: f64,
    pub iterations: usize,
    pub backtracks: usize,
    pub converged: bool,
}

/// Project every atom onto the unit l2 ball (in place).
pub fn project_dict(d: &mut NdTensor) {
    let k = d.dims()[0];
    for ki in 0..k {
        project_l2_ball(d.slice0_mut(ki), 1.0);
    }
}

/// Run PGD from `d0`.
pub fn update_dict(stats: &DictStats, d0: &NdTensor, lambda: f64, cfg: &PgdConfig) -> PgdResult {
    let mut d = d0.clone();
    project_dict(&mut d);
    let mut cost = cost_from_stats(stats, &d, lambda);
    let mut step = initial_step(stats);
    let mut backtracks = 0usize;
    let mut converged = false;
    let mut iterations = 0usize;

    for it in 0..cfg.max_iter {
        iterations = it + 1;
        let g = grad_from_stats(stats, &d);
        let mut accepted = false;
        for _ in 0..cfg.max_backtrack {
            let mut d_try = d.clone();
            d_try.axpy(-step, &g);
            project_dict(&mut d_try);
            let delta = d.sub(&d_try);
            let decrease_needed = cfg.c1 * g.dot(&delta);
            let cost_try = cost_from_stats(stats, &d_try, lambda);
            // Armijo condition for projected gradient: sufficient
            // decrease along the projected step.
            if cost_try <= cost - decrease_needed.max(0.0) && cost_try <= cost {
                let rel = (cost - cost_try) / cost.abs().max(1e-300);
                d = d_try;
                cost = cost_try;
                step *= cfg.grow;
                accepted = true;
                if rel < cfg.tol {
                    converged = true;
                }
                break;
            }
            step *= cfg.shrink;
            backtracks += 1;
        }
        if !accepted || converged {
            converged = converged || !accepted;
            break;
        }
    }

    PgdResult { d, cost, iterations, backtracks, converged }
}

/// Conservative initial step `1 / trace-norm estimate of the phi
/// operator` (Lipschitz upper bound: `sum_tau |phi[., .][tau]|` row sums).
fn initial_step(stats: &DictStats) -> f64 {
    let k = stats.phi.dims()[0];
    let cc_sp: usize = stats.phi.dims()[2..].iter().product();
    let mut lip = 0.0f64;
    for k0 in 0..k {
        let mut row = 0.0;
        for k1 in 0..k {
            let base = (k0 * k + k1) * cc_sp;
            row += stats.phi.data()[base..base + cc_sp]
                .iter()
                .map(|x| x.abs())
                .sum::<f64>();
        }
        lip = lip.max(row);
    }
    1.0 / lip.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dict::phi_psi::compute_stats;
    use crate::util::rng::Pcg64;

    fn setup(seed: u64) -> (DictStats, NdTensor) {
        let mut rng = Pcg64::seeded(seed);
        let z = NdTensor::from_vec(&[2, 60], rng.bernoulli_gaussian_vec(120, 0.1, 0.0, 3.0));
        let d_true = NdTensor::from_vec(&[2, 1, 6], {
            let mut v = rng.normal_vec(12);
            for a in v.chunks_mut(6) {
                let n = a.iter().map(|x| x * x).sum::<f64>().sqrt();
                for x in a.iter_mut() {
                    *x /= n;
                }
            }
            v
        });
        let x = crate::conv::reconstruct(&z, &d_true);
        let stats = compute_stats(&z, &x, &[6]);
        (stats, d_true)
    }

    #[test]
    fn pgd_decreases_cost() {
        let (stats, d_true) = setup(1);
        let mut rng = Pcg64::seeded(2);
        let d0 = NdTensor::from_vec(d_true.dims(), rng.normal_vec(d_true.len()));
        let c0 = {
            let mut d = d0.clone();
            project_dict(&mut d);
            cost_from_stats(&stats, &d, 1.0)
        };
        let r = update_dict(&stats, &d0, 1.0, &PgdConfig::default());
        assert!(r.cost <= c0, "{} vs {c0}", r.cost);
        assert!(r.iterations > 0);
    }

    #[test]
    fn pgd_keeps_atoms_feasible() {
        let (stats, d_true) = setup(3);
        let mut rng = Pcg64::seeded(4);
        let d0 = NdTensor::from_vec(d_true.dims(), rng.normal_vec(d_true.len())).scale(5.0);
        let r = update_dict(&stats, &d0, 1.0, &PgdConfig::default());
        for k in 0..r.d.dims()[0] {
            let n: f64 = r.d.slice0(k).iter().map(|x| x * x).sum();
            assert!(n <= 1.0 + 1e-9, "atom {k} infeasible: {n}");
        }
    }

    #[test]
    fn pgd_recovers_true_dict_from_true_codes() {
        // X was generated exactly as Z * D_true with unit-norm atoms, so
        // D_true is a minimizer. Starting nearby, PGD should approach a
        // cost no worse than D_true's.
        let (stats, d_true) = setup(5);
        let mut rng = Pcg64::seeded(6);
        let mut d0 = d_true.clone();
        for v in d0.data_mut().iter_mut() {
            *v += 0.1 * rng.normal();
        }
        let r = update_dict(
            &stats,
            &d0,
            1.0,
            &PgdConfig { max_iter: 300, tol: 1e-12, ..Default::default() },
        );
        let c_true = cost_from_stats(&stats, &d_true, 1.0);
        assert!(
            r.cost <= c_true + 1e-5 * (1.0 + c_true.abs()),
            "{} vs true {c_true}",
            r.cost
        );
    }

    #[test]
    fn projection_is_idempotent_inside_ball() {
        let (stats, d_true) = setup(7);
        let r1 = update_dict(&stats, &d_true, 1.0, &PgdConfig { max_iter: 1, ..Default::default() });
        let r2 = update_dict(&stats, &r1.d, 1.0, &PgdConfig { max_iter: 1, ..Default::default() });
        assert!(r2.cost <= r1.cost + 1e-12);
    }
}
