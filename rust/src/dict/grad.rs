//! Dictionary gradient and objective from the sufficient statistics.
//!
//! With `phi = Z~ * Z |_Phi` and `psi = Z~ * X |_Theta` (eq. 16):
//!
//! ```text
//! grad_D F[k, p, l] = sum_k' sum_{tau in Phi} phi[k,k'][tau] D_k'[p, l - tau]  -  psi[k][p, l]
//! F(Z, D) = 1/2 ||X||^2 - <D, psi> + 1/2 sum_{k,k',tau} phi[k,k'][tau] C[k',k][tau]
//!           (+ lambda ||Z||_1)
//! ```
//!
//! where `C[k',k][tau] = sum_{p,m} D_k[p, m + tau] D_k'[p, m]` is the
//! atom cross-correlation tensor. Both are `O(K^2 P |Theta| (2L)^d)` —
//! independent of the signal size.

use crate::dict::phi_psi::DictStats;
use crate::tensor::NdTensor;

/// `grad_D F` as a `[K, P, L..]` tensor.
pub fn grad_from_stats(stats: &DictStats, d: &NdTensor) -> NdTensor {
    let (k_tot, p_tot, ldims) = crate::conv::split_dict(d.dims());
    let cc_dims: Vec<usize> = ldims.iter().map(|&l| 2 * l - 1).collect();
    let cc_sp: usize = cc_dims.iter().product();
    let atom_sp: usize = ldims.iter().product();
    let mut grad = stats.psi.scale(-1.0);

    match ldims.len() {
        1 => {
            let l = ldims[0] as i64;
            for k in 0..k_tot {
                for k1 in 0..k_tot {
                    let phi_row = &stats.phi.data()[(k * k_tot + k1) * cc_sp..][..cc_sp];
                    let dk1 = d.slice0(k1);
                    for p in 0..p_tot {
                        let dp = &dk1[p * atom_sp..(p + 1) * atom_sp];
                        let out = &mut grad.data_mut()[(k * p_tot + p) * atom_sp..][..atom_sp];
                        for li in 0..l {
                            let mut acc = 0.0;
                            // tau in [-L+1, L) with l - tau in [0, L)
                            let tmin = (li - l + 1).max(1 - l);
                            let tmax = (li + 1).min(l);
                            for tau in tmin..tmax {
                                acc += phi_row[(tau + l - 1) as usize]
                                    * dp[(li - tau) as usize];
                            }
                            out[li as usize] += acc;
                        }
                    }
                }
            }
        }
        2 => {
            let (l0, l1) = (ldims[0] as i64, ldims[1] as i64);
            let cc_w = cc_dims[1];
            let aw = ldims[1];
            for k in 0..k_tot {
                for k1 in 0..k_tot {
                    let phi_row = &stats.phi.data()[(k * k_tot + k1) * cc_sp..][..cc_sp];
                    let dk1 = d.slice0(k1);
                    for p in 0..p_tot {
                        let dp = &dk1[p * atom_sp..(p + 1) * atom_sp];
                        let out = &mut grad.data_mut()[(k * p_tot + p) * atom_sp..][..atom_sp];
                        for li in 0..l0 {
                            for lj in 0..l1 {
                                let mut acc = 0.0;
                                let t0min = (li - l0 + 1).max(1 - l0);
                                let t0max = (li + 1).min(l0);
                                let t1min = (lj - l1 + 1).max(1 - l1);
                                let t1max = (lj + 1).min(l1);
                                for t0 in t0min..t0max {
                                    let prow = ((t0 + l0 - 1) as usize) * cc_w;
                                    let drow = ((li - t0) as usize) * aw;
                                    for t1 in t1min..t1max {
                                        acc += phi_row[prow + (t1 + l1 - 1) as usize]
                                            * dp[drow + (lj - t1) as usize];
                                    }
                                }
                                out[(li as usize) * aw + lj as usize] += acc;
                            }
                        }
                    }
                }
            }
        }
        _ => {
            // Generic d via Rect iteration.
            use crate::tensor::shape::Rect;
            let theta = Rect::full(ldims);
            let phi_box = Rect::new(
                ldims.iter().map(|&l| 1 - l as i64).collect(),
                ldims.iter().map(|&l| l as i64).collect(),
            );
            let cc_str = crate::tensor::shape::strides_of(&cc_dims);
            let a_str = crate::tensor::shape::strides_of(ldims);
            for k in 0..k_tot {
                for k1 in 0..k_tot {
                    let phi_row = &stats.phi.data()[(k * k_tot + k1) * cc_sp..][..cc_sp];
                    let dk1 = d.slice0(k1);
                    for p in 0..p_tot {
                        let dp = &dk1[p * atom_sp..(p + 1) * atom_sp];
                        let out = &mut grad.data_mut()[(k * p_tot + p) * atom_sp..][..atom_sp];
                        for l in theta.iter() {
                            let mut acc = 0.0;
                            for tau in phi_box.iter() {
                                let idx: Vec<i64> =
                                    l.iter().zip(&tau).map(|(a, b)| a - b).collect();
                                if idx.iter().zip(ldims).any(|(x, &n)| *x < 0 || *x >= n as i64) {
                                    continue;
                                }
                                let poff: usize = tau
                                    .iter()
                                    .zip(ldims)
                                    .zip(&cc_str)
                                    .map(|((t, &n), s)| (t + n as i64 - 1) as usize * s)
                                    .sum();
                                let doff: usize =
                                    idx.iter().zip(&a_str).map(|(x, s)| *x as usize * s).sum();
                                acc += phi_row[poff] * dp[doff];
                            }
                            let ooff: usize =
                                l.iter().zip(&a_str).map(|(x, s)| *x as usize * s).sum();
                            out[ooff] += acc;
                        }
                    }
                }
            }
        }
    }
    grad
}

/// Objective value from the statistics (includes the `lambda ||Z||_1`
/// term so it matches `CscProblem::cost` exactly).
pub fn cost_from_stats(stats: &DictStats, d: &NdTensor, lambda: f64) -> f64 {
    let dtd = crate::conv::compute_dtd(d);
    let quad = stats.phi.dot(&dtd_transposed(&dtd));
    0.5 * stats.x_norm_sq - d.dot(&stats.psi) + 0.5 * quad + lambda * stats.z_l1
}

/// `C[k,k'][tau] -> C[k',k][tau]` (the contraction in `cost_from_stats`
/// pairs `phi[k,k']` with `dtd[k',k]`).
fn dtd_transposed(dtd: &NdTensor) -> NdTensor {
    let k = dtd.dims()[0];
    let cc_sp: usize = dtd.dims()[2..].iter().product();
    let mut out = NdTensor::zeros(dtd.dims());
    for k0 in 0..k {
        for k1 in 0..k {
            let src = &dtd.data()[(k1 * k + k0) * cc_sp..][..cc_sp];
            out.data_mut()[(k0 * k + k1) * cc_sp..][..cc_sp].copy_from_slice(src);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::csc::problem::CscProblem;
    use crate::dict::phi_psi::compute_stats;
    use crate::util::rng::Pcg64;

    fn setup(seed: u64, two_d: bool) -> (NdTensor, NdTensor, NdTensor, Vec<usize>) {
        let mut rng = Pcg64::seeded(seed);
        if two_d {
            let z = NdTensor::from_vec(&[2, 10, 9], rng.bernoulli_gaussian_vec(180, 0.15, 0.0, 2.0));
            let x = NdTensor::from_vec(&[2, 13, 12], rng.normal_vec(312));
            let d = NdTensor::from_vec(&[2, 2, 4, 4], rng.normal_vec(64));
            (z, x, d, vec![4, 4])
        } else {
            let z = NdTensor::from_vec(&[3, 40], rng.bernoulli_gaussian_vec(120, 0.15, 0.0, 2.0));
            let x = NdTensor::from_vec(&[2, 45], rng.normal_vec(90));
            let d = NdTensor::from_vec(&[3, 2, 6], rng.normal_vec(36));
            (z, x, d, vec![6])
        }
    }

    #[test]
    fn cost_from_stats_matches_direct() {
        for two_d in [false, true] {
            let (z, x, d, l) = setup(1, two_d);
            let stats = compute_stats(&z, &x, &l);
            let lambda = 0.3;
            let direct = {
                let p = CscProblem::new(x.clone(), d.clone(), lambda);
                p.cost(&z)
            };
            let from_stats = cost_from_stats(&stats, &d, lambda);
            assert!(
                (direct - from_stats).abs() < 1e-8 * (1.0 + direct.abs()),
                "2d={two_d}: {direct} vs {from_stats}"
            );
        }
    }

    #[test]
    fn grad_matches_finite_differences() {
        for two_d in [false, true] {
            let (z, x, d, l) = setup(2, two_d);
            let stats = compute_stats(&z, &x, &l);
            let grad = grad_from_stats(&stats, &d);
            let f0 = cost_from_stats(&stats, &d, 1.0);
            let eps = 1e-6;
            let mut rng = Pcg64::seeded(3);
            for _ in 0..12 {
                let i = rng.below(d.len());
                let mut dp = d.clone();
                dp.data_mut()[i] += eps;
                let f1 = cost_from_stats(&stats, &dp, 1.0);
                let fd = (f1 - f0) / eps;
                assert!(
                    (fd - grad.get(i)).abs() < 1e-3 * (1.0 + fd.abs()),
                    "2d={two_d} coord {i}: fd {fd} vs grad {}",
                    grad.get(i)
                );
            }
        }
    }

    #[test]
    fn grad_matches_convolutional_form() {
        // grad = Z~ * (Z*D - X) restricted to Theta == psi-form identity.
        let (z, x, d, l) = setup(4, false);
        let stats = compute_stats(&z, &x, &l);
        let grad = grad_from_stats(&stats, &d);
        let recon = crate::conv::reconstruct(&z, &d);
        let direct = crate::conv::compute_psi(&z, &recon.sub(&x), &l);
        assert!(grad.allclose(&direct, 1e-8));
    }

    #[test]
    fn grad_zero_at_least_squares_solution_direction() {
        // <grad, D> relates to the directional derivative; at D the
        // derivative along -grad must be non-positive.
        let (z, x, d, l) = setup(5, false);
        let stats = compute_stats(&z, &x, &l);
        let grad = grad_from_stats(&stats, &d);
        let f0 = cost_from_stats(&stats, &d, 1.0);
        let step = 1e-4 / (1.0 + grad.norm2());
        let d1 = d.sub(&grad.scale(step));
        let f1 = cost_from_stats(&stats, &d1, 1.0);
        assert!(f1 <= f0);
    }
}
