//! Sufficient statistics for the dictionary update (§4.2, eq. 16–17):
//!
//! ```text
//! phi[k,k'][tau] = sum_u Z_k[u] Z_k'[u + tau]   tau in Phi = [-L+1, L)
//! psi[k][p, l]   = sum_u Z_k[u] X[p, u + l]     l   in Theta = [0, L)
//! ```
//!
//! Given `(phi, psi)`, both the gradient and the value of the
//! dictionary objective are computable in `O(K^2 P |Theta|^2)` —
//! independent of the signal size. The map-reduce version splits the
//! sums over worker cells exactly as the paper distributes them over
//! the CSC worker grid, and the same windowed core
//! ([`local_stats_windows`]) is what each resident pool worker runs on
//! its own Z windows (`ComputeStats` phase) — so the reduced partials
//! are bit-for-bit the same sums whichever side computes them.
//!
//! The dense-map-reduce vs sparse-sequential dispatch threshold is
//! tunable via `DICODILE_PHIPSI_DENSITY` (mirroring the
//! `DICODILE_FFT_CROSSOVER` seam); the path taken is reported through
//! [`compute_stats_auto`] and recorded in the CDL trace.

use std::sync::OnceLock;

use crate::conv;
use crate::csc::beta::ZWindow;
use crate::csc::problem::CscProblem;
use crate::dicod::partition::{PartitionKind, WorkerGrid};
use crate::tensor::shape::Rect;
use crate::tensor::NdTensor;

/// The pair of sufficient statistics.
#[derive(Clone, Debug)]
pub struct DictStats {
    /// `[K, K, (2L-1)..]`.
    pub phi: NdTensor,
    /// `[K, P, L..]`.
    pub psi: NdTensor,
    /// `||X||_2^2` (completes the objective).
    pub x_norm_sq: f64,
    /// `||Z||_1` (completes the objective).
    pub z_l1: f64,
}

/// Activation density below which the sequential sparse nonzero-pair
/// path beats the dense map-reduce (`DICODILE_PHIPSI_DENSITY`,
/// default 0.05). Post-CSC activations are usually far below it.
pub fn phipsi_density_threshold() -> f64 {
    static T: OnceLock<f64> = OnceLock::new();
    *T.get_or_init(|| parse_phipsi_density(std::env::var("DICODILE_PHIPSI_DENSITY").ok()))
}

/// Parse helper for the `DICODILE_PHIPSI_DENSITY` override (exposed
/// separately so the policy is testable without touching the process
/// environment; the cached reader above freezes on first use).
pub fn parse_phipsi_density(raw: Option<String>) -> f64 {
    raw.and_then(|s| s.parse().ok())
        .filter(|t: &f64| t.is_finite() && *t >= 0.0)
        .unwrap_or(0.05)
}

/// Sequential computation of `(phi, psi)`.
pub fn compute_stats(z: &NdTensor, x: &NdTensor, ldims: &[usize]) -> DictStats {
    DictStats {
        phi: conv::compute_phi(z, ldims),
        psi: conv::compute_psi(z, x, ldims),
        x_norm_sq: x.norm_sq(),
        z_l1: z.norm1(),
    }
}

/// Map-reduce computation over `n_workers` threads: each worker
/// computes the partial sums restricted to its cell `S_w` (eq. 17) and
/// the partials are reduced by summation.
pub fn compute_stats_parallel(
    z: &NdTensor,
    x: &NdTensor,
    ldims: &[usize],
    n_workers: usize,
) -> DictStats {
    compute_stats_auto(z, x, ldims, n_workers).0
}

/// As [`compute_stats_parallel`], additionally reporting which path ran
/// (`"sparse-seq"` or `"dense-par"`) for the CDL trace.
pub fn compute_stats_auto(
    z: &NdTensor,
    x: &NdTensor,
    ldims: &[usize],
    n_workers: usize,
) -> (DictStats, &'static str) {
    let zsp: Vec<usize> = z.dims()[1..].to_vec();
    let w = n_workers
        .min(zsp[0]) // at least 1 row per worker
        .max(1);
    // Post-CSC activations are very sparse; the sequential sparse
    // nonzero-pair path (conv::compute_phi/psi) beats the dense
    // map-reduce by an order of magnitude there, so prefer it. The
    // dense map-reduce remains the multi-core path for dense Z.
    let density = z.nnz() as f64 / z.len().max(1) as f64;
    if w == 1 || density < phipsi_density_threshold() {
        return (compute_stats(z, x, ldims), "sparse-seq");
    }
    let grid = WorkerGrid::new(&zsp, ldims, w, PartitionKind::Grid);
    let mut partials: Vec<Option<(NdTensor, NdTensor)>> = vec![None; w];
    std::thread::scope(|scope| {
        for (rank, slot) in partials.iter_mut().enumerate() {
            let grid = &grid;
            scope.spawn(move || {
                *slot = Some(local_stats(z, x, ldims, grid, rank));
            });
        }
    });
    let mut it = partials.into_iter().map(|p| p.unwrap());
    let (mut phi, mut psi) = it.next().unwrap();
    for (p2, s2) in it {
        phi.add_assign(&p2);
        psi.add_assign(&s2);
    }
    (
        DictStats { phi, psi, x_norm_sq: x.norm_sq(), z_l1: z.norm1() },
        "dense-par",
    )
}

/// Engine-aware statistics dispatch: like [`compute_stats_auto`], but
/// with a third candidate path — the half-spectrum FFT cross-spectra
/// kernel ([`conv::CorrEngine::phi_psi_fft`]) — for the dense-Z regime
/// where transform cost beats both direct kernels. Sparse post-CSC
/// activations still take the nonzero-pair path; the FFT path kicks in
/// when the activation is dense (early iterations, FISTA iterates,
/// online chunks before the code sparsifies) *and* the engine's flop
/// model says the transforms win. Reported paths: `"sparse-seq"`,
/// `"dense-par"`, `"fft"`.
pub fn compute_stats_with_engine(
    z: &NdTensor,
    x: &NdTensor,
    ldims: &[usize],
    n_workers: usize,
    corr: &conv::CorrEngine,
) -> (DictStats, &'static str) {
    let density = z.nnz() as f64 / z.len().max(1) as f64;
    let tdims: Vec<usize> = x.dims()[1..].to_vec();
    if density >= phipsi_density_threshold() && corr.prefers_fft_stats(z, &tdims) {
        let (phi, psi) = corr.phi_psi_fft(z, x);
        return (
            DictStats { phi, psi, x_norm_sq: x.norm_sq(), z_l1: z.norm1() },
            "fft",
        );
    }
    compute_stats_auto(z, x, ldims, n_workers)
}

/// Partial `(phi^w, psi^w)` with the outer sum restricted to `S_w`,
/// computed from *global* tensors (the thread map-reduce path): copies
/// the cell/extended windows and defers to [`local_stats_windows`].
fn local_stats(
    z: &NdTensor,
    x: &NdTensor,
    ldims: &[usize],
    grid: &WorkerGrid,
    rank: usize,
) -> (NdTensor, NdTensor) {
    let k_tot = z.dims()[0];
    let p_tot = x.dims()[0];
    let zsp: Vec<usize> = z.dims()[1..].to_vec();
    let tdims: Vec<usize> = x.dims()[1..].to_vec();
    let cell = grid.cell(rank);
    let ext = grid.extended_cell(rank);

    let copy_window = |src: &[f64], sdims: &[usize], win: &Rect| -> Vec<f64> {
        let str_ = crate::tensor::shape::strides_of(sdims);
        let mut out = Vec::with_capacity(win.size());
        for pt in win.iter() {
            let off: usize = pt.iter().zip(&str_).map(|(x, s)| *x as usize * s).sum();
            out.push(src[off]);
        }
        out
    };

    let cells: Vec<Vec<f64>> = (0..k_tot)
        .map(|k| copy_window(z.slice0(k), &zsp, &cell))
        .collect();
    let exts: Vec<Vec<f64>> = (0..k_tot)
        .map(|k| copy_window(z.slice0(k), &zsp, &ext))
        .collect();

    // psi partner: X over [cell.lo, cell.hi + L - 1) — always inside
    // the observation domain.
    let xwin = Rect::new(
        cell.lo.clone(),
        cell.hi.iter().zip(ldims).map(|(h, &l)| h + l as i64 - 1).collect(),
    );
    let mut xdims = vec![p_tot];
    xdims.extend_from_slice(&xwin.extents());
    let mut xw = NdTensor::zeros(&xdims);
    let xwsp: usize = xwin.extents().iter().product();
    for p in 0..p_tot {
        let win = copy_window(x.slice0(p), &tdims, &xwin);
        xw.data_mut()[p * xwsp..(p + 1) * xwsp].copy_from_slice(&win);
    }

    local_stats_windows(&cells, &cell, &exts, &ext, &xw, ldims)
}

/// The windowed φ/ψ partial core shared by the thread map-reduce and
/// the resident pool workers: `cells[k]` holds `Z_k` over the worker's
/// own cell, `exts[k]` over the extended cell (the correlation partner
/// of eq. 17), and `xw` the signal window `[P, cell + L - 1]` anchored
/// at `cell.lo`.
pub fn local_stats_windows(
    cells: &[Vec<f64>],
    cell: &Rect,
    exts: &[Vec<f64>],
    ext: &Rect,
    xw: &NdTensor,
    ldims: &[usize],
) -> (NdTensor, NdTensor) {
    let k_tot = cells.len();
    let p_tot = xw.dims()[0];
    let cell_ext = cell.extents();
    let ext_ext = ext.extents();

    let cc_dims: Vec<usize> = ldims.iter().map(|&l| 2 * l - 1).collect();
    let cc_sp: usize = cc_dims.iter().product();
    let mut phi_dims = vec![k_tot, k_tot];
    phi_dims.extend_from_slice(&cc_dims);
    let mut phi = NdTensor::zeros(&phi_dims);

    // delta window for phi: tau in [-L+1, L), shifted by (cell.lo - ext.lo).
    let shift: Vec<i64> = cell.lo.iter().zip(&ext.lo).map(|(c, e)| c - e).collect();
    let lo: Vec<i64> = ldims
        .iter()
        .zip(&shift)
        .map(|(&l, s)| 1 - l as i64 + s)
        .collect();
    let hi: Vec<i64> = ldims.iter().zip(&shift).map(|(&l, s)| l as i64 + s).collect();

    for k0 in 0..k_tot {
        for k1 in 0..k_tot {
            let (cc, _) = conv::cross_corr_range_auto(
                &cells[k0], &cell_ext, &exts[k1], &ext_ext, &lo, &hi,
            );
            let base = (k0 * k_tot + k1) * cc_sp;
            for (o, v) in phi.data_mut()[base..base + cc_sp].iter_mut().zip(&cc) {
                *o += v;
            }
        }
    }

    let xwin_ext: Vec<usize> = xw.dims()[1..].to_vec();
    let atom_sp: usize = ldims.iter().product();
    let mut psi_dims = vec![k_tot, p_tot];
    psi_dims.extend_from_slice(ldims);
    let mut psi = NdTensor::zeros(&psi_dims);
    let plo: Vec<i64> = ldims.iter().map(|_| 0).collect();
    let phi_hi: Vec<i64> = ldims.iter().map(|&l| l as i64).collect();
    for p in 0..p_tot {
        let xp = xw.slice0(p);
        for (k, zc) in cells.iter().enumerate() {
            let (cc, _) = conv::cross_corr_range_auto(
                zc, &cell_ext, xp, &xwin_ext, &plo, &phi_hi,
            );
            let base = (k * p_tot + p) * atom_sp;
            for (o, v) in psi.data_mut()[base..base + atom_sp].iter_mut().zip(&cc) {
                *o += v;
            }
        }
    }

    (phi, psi)
}

/// φ/ψ partials for a resident pool worker, read from its own
/// activation window (`ComputeStats` phase): copies the cell and
/// extended-cell slices out of `z`, slices the signal window through
/// the problem, and runs the shared windowed core. Also returns the
/// cell-restricted `||Z||_1` and nonzero count (reduced pool-side to
/// complete the objective and the trace).
pub fn worker_stats_partials(
    problem: &CscProblem,
    z: &ZWindow,
    cell: &Rect,
    ext: &Rect,
) -> (NdTensor, NdTensor, f64, usize) {
    let k_tot = problem.n_atoms();
    let copy = |win: &Rect| -> Vec<Vec<f64>> {
        (0..k_tot)
            .map(|k| win.iter().map(|u| z.at(k, &u)).collect())
            .collect()
    };
    let cells = copy(cell);
    let exts = copy(ext);
    let mut z_l1 = 0.0;
    let mut z_nnz = 0usize;
    for row in &cells {
        for v in row {
            if *v != 0.0 {
                z_l1 += v.abs();
                z_nnz += 1;
            }
        }
    }
    let xw = problem.signal_window(&cell.lo, &cell.extents());
    let (phi, psi) = local_stats_windows(&cells, cell, &exts, ext, &xw, problem.atom_dims());
    (phi, psi, z_l1, z_nnz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn workload_1d(seed: u64) -> (NdTensor, NdTensor, Vec<usize>) {
        let mut rng = Pcg64::seeded(seed);
        let z = NdTensor::from_vec(&[3, 50], rng.bernoulli_gaussian_vec(150, 0.1, 0.0, 3.0));
        let x = NdTensor::from_vec(&[2, 57], rng.normal_vec(114));
        (z, x, vec![8])
    }

    fn workload_2d(seed: u64) -> (NdTensor, NdTensor, Vec<usize>) {
        let mut rng = Pcg64::seeded(seed);
        let z = NdTensor::from_vec(&[2, 20, 18], rng.bernoulli_gaussian_vec(720, 0.1, 0.0, 3.0));
        let x = NdTensor::from_vec(&[1, 24, 22], rng.normal_vec(528));
        (z, x, vec![5, 5])
    }

    #[test]
    fn parallel_matches_sequential_1d() {
        let (z, x, l) = workload_1d(1);
        let seq = compute_stats(&z, &x, &l);
        for w in [2usize, 3, 5] {
            let par = compute_stats_parallel(&z, &x, &l, w);
            assert!(par.phi.allclose(&seq.phi, 1e-10), "phi mismatch W={w}");
            assert!(par.psi.allclose(&seq.psi, 1e-10), "psi mismatch W={w}");
        }
    }

    #[test]
    fn parallel_matches_sequential_2d() {
        let (z, x, l) = workload_2d(2);
        let seq = compute_stats(&z, &x, &l);
        for w in [2usize, 4, 6] {
            let par = compute_stats_parallel(&z, &x, &l, w);
            assert!(par.phi.allclose(&seq.phi, 1e-10), "phi mismatch W={w}");
            assert!(par.psi.allclose(&seq.psi, 1e-10), "psi mismatch W={w}");
        }
    }

    #[test]
    fn stats_scalars() {
        let (z, x, l) = workload_1d(3);
        let s = compute_stats(&z, &x, &l);
        assert!((s.x_norm_sq - x.norm_sq()).abs() < 1e-12);
        assert!((s.z_l1 - z.norm1()).abs() < 1e-12);
    }

    #[test]
    fn auto_reports_the_path_taken() {
        let (z, x, l) = workload_1d(4);
        // density ~0.1 with the default 0.05 threshold -> dense path.
        if parse_phipsi_density(std::env::var("DICODILE_PHIPSI_DENSITY").ok()) == 0.05 {
            let (_, path) = compute_stats_auto(&z, &x, &l, 3);
            assert_eq!(path, "dense-par");
        }
        // one worker is always the sequential path
        let (_, path1) = compute_stats_auto(&z, &x, &l, 1);
        assert_eq!(path1, "sparse-seq");
        // near-empty z -> sparse path regardless of workers
        let zs = NdTensor::zeros(z.dims());
        let (_, path2) = compute_stats_auto(&zs, &x, &l, 4);
        assert_eq!(path2, "sparse-seq");
    }

    #[test]
    fn engine_dispatch_matches_direct_stats() {
        // Whatever path the engine-aware dispatch picks, the statistics
        // must equal the direct sequential reference.
        for (z, x, l) in [workload_1d(11), workload_2d(12)] {
            let k = z.dims()[0];
            let p = x.dims()[0];
            let mut rng = Pcg64::seeded(13);
            let mut ddims = vec![k, p];
            ddims.extend_from_slice(&l);
            let d = NdTensor::from_vec(&ddims, rng.normal_vec(ddims.iter().product()));
            let corr = crate::conv::CorrEngine::new(d);
            let seq = compute_stats(&z, &x, &l);
            for w in [1usize, 3] {
                let (s, path) = compute_stats_with_engine(&z, &x, &l, w, &corr);
                assert!(
                    matches!(path, "sparse-seq" | "dense-par" | "fft"),
                    "unknown path {path}"
                );
                let tol = 1e-8 * (1.0 + seq.phi.norm_inf());
                assert!(s.phi.allclose(&seq.phi, tol), "phi mismatch via {path}");
                let tol = 1e-8 * (1.0 + seq.psi.norm_inf());
                assert!(s.psi.allclose(&seq.psi, tol), "psi mismatch via {path}");
                assert!((s.x_norm_sq - seq.x_norm_sq).abs() < 1e-10);
                assert!((s.z_l1 - seq.z_l1).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn engine_dispatch_keeps_sparse_path_for_sparse_z() {
        // Near-empty activations must never pay transform cost.
        let (z, x, l) = workload_1d(14);
        let zs = NdTensor::zeros(z.dims());
        let mut rng = Pcg64::seeded(15);
        let d = NdTensor::from_vec(&[3, 2, 8], rng.normal_vec(48));
        let corr = crate::conv::CorrEngine::new(d);
        let (_, path) = compute_stats_with_engine(&zs, &x, &l, 4, &corr);
        assert_eq!(path, "sparse-seq");
    }

    #[test]
    fn density_threshold_parsing() {
        assert_eq!(parse_phipsi_density(None), 0.05);
        assert_eq!(parse_phipsi_density(Some("0.2".into())), 0.2);
        assert_eq!(parse_phipsi_density(Some("0".into())), 0.0);
        // garbage / invalid values fall back to the default
        assert_eq!(parse_phipsi_density(Some("dense".into())), 0.05);
        assert_eq!(parse_phipsi_density(Some("-1".into())), 0.05);
        assert_eq!(parse_phipsi_density(Some("NaN".into())), 0.05);
    }

    #[test]
    fn worker_partials_from_zwindow_match_local_stats() {
        // The resident-worker partial (computed from a ZWindow wider
        // than the extended cell, as the pool holds it) must equal the
        // global-tensor map-reduce partial for every rank.
        let (z, x, l) = workload_2d(5);
        let zsp: Vec<usize> = z.dims()[1..].to_vec();
        let problem = CscProblem::new(x.clone(), {
            let mut rng = Pcg64::seeded(6);
            NdTensor::from_vec(&[2, 1, 5, 5], rng.normal_vec(50))
        }, 0.5);
        let grid = WorkerGrid::new(&zsp, &l, 4, PartitionKind::Grid);
        let rim: Vec<usize> = l.iter().map(|&li| 2 * (li - 1)).collect();
        for rank in 0..grid.n_workers() {
            let cell = grid.cell(rank);
            let ext = grid.extended_cell(rank);
            let zwin = cell.dilate(&rim).intersect(&Rect::full(&zsp));
            let mut zw = ZWindow::zeros(z.dims()[0], &zwin.lo, &zwin.extents());
            zw.load_from_global(&z);
            let (phi, psi, z_l1, nnz) = worker_stats_partials(&problem, &zw, &cell, &ext);
            let (phi_ref, psi_ref) = local_stats(&z, &x, &l, &grid, rank);
            assert!(phi.allclose(&phi_ref, 1e-10), "phi rank {rank}");
            assert!(psi.allclose(&psi_ref, 1e-10), "psi rank {rank}");
            // l1/nnz restricted to the cell
            let mut want_l1 = 0.0;
            let mut want_nnz = 0usize;
            for k in 0..z.dims()[0] {
                for u in cell.iter() {
                    let idx: Vec<usize> = std::iter::once(k)
                        .chain(u.iter().map(|v| *v as usize))
                        .collect();
                    let v = z.at(&idx);
                    if v != 0.0 {
                        want_l1 += v.abs();
                        want_nnz += 1;
                    }
                }
            }
            assert!((z_l1 - want_l1).abs() < 1e-12);
            assert_eq!(nnz, want_nnz);
        }
    }
}
