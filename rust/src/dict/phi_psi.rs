//! Sufficient statistics for the dictionary update (§4.2, eq. 16–17):
//!
//! ```text
//! phi[k,k'][tau] = sum_u Z_k[u] Z_k'[u + tau]   tau in Phi = [-L+1, L)
//! psi[k][p, l]   = sum_u Z_k[u] X[p, u + l]     l   in Theta = [0, L)
//! ```
//!
//! Given `(phi, psi)`, both the gradient and the value of the
//! dictionary objective are computable in `O(K^2 P |Theta|^2)` —
//! independent of the signal size. The map-reduce version splits the
//! sums over worker cells exactly as the paper distributes them over
//! the CSC worker grid.

use crate::conv;
use crate::dicod::partition::{PartitionKind, WorkerGrid};
use crate::tensor::shape::Rect;
use crate::tensor::NdTensor;

/// The pair of sufficient statistics.
#[derive(Clone, Debug)]
pub struct DictStats {
    /// `[K, K, (2L-1)..]`.
    pub phi: NdTensor,
    /// `[K, P, L..]`.
    pub psi: NdTensor,
    /// `||X||_2^2` (completes the objective).
    pub x_norm_sq: f64,
    /// `||Z||_1` (completes the objective).
    pub z_l1: f64,
}

/// Sequential computation of `(phi, psi)`.
pub fn compute_stats(z: &NdTensor, x: &NdTensor, ldims: &[usize]) -> DictStats {
    DictStats {
        phi: conv::compute_phi(z, ldims),
        psi: conv::compute_psi(z, x, ldims),
        x_norm_sq: x.norm_sq(),
        z_l1: z.norm1(),
    }
}

/// Map-reduce computation over `n_workers` threads: each worker
/// computes the partial sums restricted to its cell `S_w` (eq. 17) and
/// the partials are reduced by summation.
pub fn compute_stats_parallel(
    z: &NdTensor,
    x: &NdTensor,
    ldims: &[usize],
    n_workers: usize,
) -> DictStats {
    let zsp: Vec<usize> = z.dims()[1..].to_vec();
    let w = n_workers
        .min(zsp[0]) // at least 1 row per worker
        .max(1);
    // Post-CSC activations are very sparse; the sequential sparse
    // nonzero-pair path (conv::compute_phi/psi) beats the dense
    // map-reduce by an order of magnitude there, so prefer it. The
    // dense map-reduce remains the multi-core path for dense Z.
    let density = z.nnz() as f64 / z.len().max(1) as f64;
    if w == 1 || density < 0.05 {
        return compute_stats(z, x, ldims);
    }
    let grid = WorkerGrid::new(&zsp, ldims, w, PartitionKind::Grid);
    let mut partials: Vec<Option<(NdTensor, NdTensor)>> = vec![None; w];
    std::thread::scope(|scope| {
        for (rank, slot) in partials.iter_mut().enumerate() {
            let grid = &grid;
            scope.spawn(move || {
                *slot = Some(local_stats(z, x, ldims, grid, rank));
            });
        }
    });
    let mut it = partials.into_iter().map(|p| p.unwrap());
    let (mut phi, mut psi) = it.next().unwrap();
    for (p2, s2) in it {
        phi.add_assign(&p2);
        psi.add_assign(&s2);
    }
    DictStats { phi, psi, x_norm_sq: x.norm_sq(), z_l1: z.norm1() }
}

/// Partial `(phi^w, psi^w)` with the outer sum restricted to `S_w`.
fn local_stats(
    z: &NdTensor,
    x: &NdTensor,
    ldims: &[usize],
    grid: &WorkerGrid,
    rank: usize,
) -> (NdTensor, NdTensor) {
    let k_tot = z.dims()[0];
    let p_tot = x.dims()[0];
    let zsp: Vec<usize> = z.dims()[1..].to_vec();
    let tdims: Vec<usize> = x.dims()[1..].to_vec();
    let cell = grid.cell(rank);
    let ext = grid.extended_cell(rank);
    let cell_ext = cell.extents();
    let ext_ext = ext.extents();

    // Copy the cell slice of each Z_k and the extended slice used as
    // the correlation partner.
    let copy_window = |src: &[f64], sdims: &[usize], win: &Rect| -> Vec<f64> {
        let str_ = crate::tensor::shape::strides_of(sdims);
        let mut out = Vec::with_capacity(win.size());
        for pt in win.iter() {
            let off: usize = pt.iter().zip(&str_).map(|(x, s)| *x as usize * s).sum();
            out.push(src[off]);
        }
        out
    };

    let cc_dims: Vec<usize> = ldims.iter().map(|&l| 2 * l - 1).collect();
    let cc_sp: usize = cc_dims.iter().product();
    let mut phi_dims = vec![k_tot, k_tot];
    phi_dims.extend_from_slice(&cc_dims);
    let mut phi = NdTensor::zeros(&phi_dims);

    // delta window for phi: tau in [-L+1, L), shifted by (cell.lo - ext.lo).
    let shift: Vec<i64> = cell.lo.iter().zip(&ext.lo).map(|(c, e)| c - e).collect();
    let lo: Vec<i64> = ldims
        .iter()
        .zip(&shift)
        .map(|(&l, s)| 1 - l as i64 + s)
        .collect();
    let hi: Vec<i64> = ldims.iter().zip(&shift).map(|(&l, s)| l as i64 + s).collect();

    let cells: Vec<Vec<f64>> = (0..k_tot)
        .map(|k| copy_window(z.slice0(k), &zsp, &cell))
        .collect();
    let exts: Vec<Vec<f64>> = (0..k_tot)
        .map(|k| copy_window(z.slice0(k), &zsp, &ext))
        .collect();

    for k0 in 0..k_tot {
        for k1 in 0..k_tot {
            let (cc, _) = conv::cross_corr_range_auto(
                &cells[k0], &cell_ext, &exts[k1], &ext_ext, &lo, &hi,
            );
            let base = (k0 * k_tot + k1) * cc_sp;
            for (o, v) in phi.data_mut()[base..base + cc_sp].iter_mut().zip(&cc) {
                *o += v;
            }
        }
    }

    // psi: partner window of X is [cell.lo, cell.hi + L - 1) — always
    // inside the observation domain.
    let xwin = Rect::new(
        cell.lo.clone(),
        cell.hi.iter().zip(ldims).map(|(h, &l)| h + l as i64 - 1).collect(),
    );
    let xwin_ext = xwin.extents();
    let atom_sp: usize = ldims.iter().product();
    let mut psi_dims = vec![k_tot, p_tot];
    psi_dims.extend_from_slice(ldims);
    let mut psi = NdTensor::zeros(&psi_dims);
    let plo: Vec<i64> = ldims.iter().map(|_| 0).collect();
    let phi_hi: Vec<i64> = ldims.iter().map(|&l| l as i64).collect();
    for p in 0..p_tot {
        let xw = copy_window(x.slice0(p), &tdims, &xwin);
        for (k, zc) in cells.iter().enumerate() {
            let (cc, _) = conv::cross_corr_range_auto(
                zc, &cell_ext, &xw, &xwin_ext, &plo, &phi_hi,
            );
            let base = (k * p_tot + p) * atom_sp;
            for (o, v) in psi.data_mut()[base..base + atom_sp].iter_mut().zip(&cc) {
                *o += v;
            }
        }
    }

    (phi, psi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn workload_1d(seed: u64) -> (NdTensor, NdTensor, Vec<usize>) {
        let mut rng = Pcg64::seeded(seed);
        let z = NdTensor::from_vec(&[3, 50], rng.bernoulli_gaussian_vec(150, 0.1, 0.0, 3.0));
        let x = NdTensor::from_vec(&[2, 57], rng.normal_vec(114));
        (z, x, vec![8])
    }

    fn workload_2d(seed: u64) -> (NdTensor, NdTensor, Vec<usize>) {
        let mut rng = Pcg64::seeded(seed);
        let z = NdTensor::from_vec(&[2, 20, 18], rng.bernoulli_gaussian_vec(720, 0.1, 0.0, 3.0));
        let x = NdTensor::from_vec(&[1, 24, 22], rng.normal_vec(528));
        (z, x, vec![5, 5])
    }

    #[test]
    fn parallel_matches_sequential_1d() {
        let (z, x, l) = workload_1d(1);
        let seq = compute_stats(&z, &x, &l);
        for w in [2usize, 3, 5] {
            let par = compute_stats_parallel(&z, &x, &l, w);
            assert!(par.phi.allclose(&seq.phi, 1e-10), "phi mismatch W={w}");
            assert!(par.psi.allclose(&seq.psi, 1e-10), "psi mismatch W={w}");
        }
    }

    #[test]
    fn parallel_matches_sequential_2d() {
        let (z, x, l) = workload_2d(2);
        let seq = compute_stats(&z, &x, &l);
        for w in [2usize, 4, 6] {
            let par = compute_stats_parallel(&z, &x, &l, w);
            assert!(par.phi.allclose(&seq.phi, 1e-10), "phi mismatch W={w}");
            assert!(par.psi.allclose(&seq.psi, 1e-10), "psi mismatch W={w}");
        }
    }

    #[test]
    fn stats_scalars() {
        let (z, x, l) = workload_1d(3);
        let s = compute_stats(&z, &x, &l);
        assert!((s.x_norm_sq - x.norm_sq()).abs() < 1e-12);
        assert!((s.z_l1 - z.norm1()).abs() < 1e-12);
    }
}
