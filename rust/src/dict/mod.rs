//! Dictionary update: sufficient statistics (map-reduce over the worker
//! grid), gradients/objective from the statistics, and projected
//! gradient descent with Armijo line search (§4.2).

pub mod grad;
pub mod pgd;
pub mod phi_psi;

pub use grad::{cost_from_stats, grad_from_stats};
pub use pgd::{update_dict, PgdConfig, PgdResult};
pub use phi_psi::{
    compute_stats, compute_stats_auto, compute_stats_parallel, compute_stats_with_engine,
    local_stats_windows, worker_stats_partials, DictStats,
};
