//! Convolutional dictionary learning driver (Algorithm 2): alternation
//! of the distributed sparse coder and the PGD dictionary update, plus
//! initialization strategies and reporting.

pub mod batch;
pub mod driver;
pub mod init;
pub mod report;

pub use driver::{learn_dictionary, CdlConfig, CdlResult, CscBackend};
pub use init::InitStrategy;
