//! The full CDL alternating-minimization driver (Algorithm 2):
//!
//! ```text
//! repeat
//!   Z <- DiCoDiLe-Z(X, D, W)                (or sequential LGCD)
//!   (phi, psi) <- map-reduce over W workers (eq. 17)
//!   D <- PGD with Armijo line search
//! until cost variation < nu
//! ```
//!
//! Two execution modes:
//!
//! - **Persistent** (the paper's design, default for
//!   `DicodConfig::dicodile`): one resident [`WorkerPool`] serves the
//!   whole run. Workers are spawned once, keep their Z/beta windows
//!   across alternations (warm restarts), compute the φ/ψ partials
//!   locally, and full Z is gathered exactly once — for the final
//!   result. Per-iteration coordinator traffic is O(K² L^d), not
//!   O(signal).
//! - **Teardown** (sequential backend, or `Distributed` with
//!   `persistent: false`): the problem is rebuilt per iteration (X
//!   shared by `Arc`, never recloned) and the sparse coder warm-starts
//!   from the previous Z.

use std::sync::Arc;
use std::time::Instant;

use crate::cdl::init::{init_dictionary, InitStrategy};
use crate::csc::cd::{solve_cd_warm, CdConfig};
use crate::csc::problem::CscProblem;
use crate::csc::select::Strategy;
use crate::dicod::config::{Alternation, DicodConfig};
use crate::dicod::coordinator::solve_distributed_warm;
use crate::dicod::pool::{PoolReport, WorkerPool};
use crate::dict::grad::cost_from_stats;
use crate::dict::pgd::{update_dict, PgdConfig};
use crate::dict::phi_psi::compute_stats_with_engine;
use crate::tensor::NdTensor;

// The alternation loops live here; the public entry point delegates to
// the `api` facade, which owns pool residency (see `crate::api`).

/// Which sparse coder the CDL loop uses.
#[derive(Clone, Debug)]
pub enum CscBackend {
    /// Sequential LGCD (warm-started between outer iterations).
    Sequential,
    /// DiCoDiLe-Z with the given worker configuration. Runs on the
    /// resident pool when `cfg.persistent` is set (the
    /// `DicodConfig::dicodile` default), else one pool per iteration,
    /// warm-started from the previous Z.
    Distributed(DicodConfig),
    /// DiCoDiLe-Z on the resident pool, regardless of the config flag.
    /// The corpus driver keeps one resident pool per signal alive
    /// across the whole alternation for this variant (and for
    /// `Distributed` with `persistent: true`).
    Persistent(DicodConfig),
}

/// CDL driver configuration.
#[derive(Clone, Debug)]
pub struct CdlConfig {
    pub n_atoms: usize,
    pub atom_dims: Vec<usize>,
    /// `lambda = lambda_frac * lambda_max(X, D_0)`.
    pub lambda_frac: f64,
    /// Outer alternations.
    pub max_iter: usize,
    /// Stop when the relative cost variation drops below `nu`.
    pub nu: f64,
    pub csc: CscBackend,
    pub csc_tol: f64,
    pub dict_cfg: PgdConfig,
    pub init: InitStrategy,
    /// Threads for the phi/psi map-reduce (teardown mode only; the
    /// persistent pool reduces worker partials instead).
    pub stat_workers: usize,
    pub seed: u64,
    /// Print per-iteration progress to stderr.
    pub verbose: bool,
}

impl Default for CdlConfig {
    fn default() -> Self {
        CdlConfig {
            n_atoms: 5,
            atom_dims: vec![16],
            lambda_frac: 0.1,
            max_iter: 30,
            nu: 1e-5,
            csc: CscBackend::Sequential,
            csc_tol: 1e-4,
            dict_cfg: PgdConfig::default(),
            init: InitStrategy::RandomPatches,
            stat_workers: 4,
            seed: 0,
            verbose: false,
        }
    }
}

/// One outer-iteration record.
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    /// Objective after the dictionary update.
    pub cost: f64,
    /// Objective after the CSC step (before the dict update).
    pub cost_after_csc: f64,
    pub z_nnz: usize,
    pub csc_time: f64,
    pub dict_time: f64,
    pub elapsed: f64,
    /// Which φ/ψ path produced the dictionary statistics:
    /// `"sparse-seq"`, `"dense-par"`, `"fft"` or `"worker-partials"`
    /// (`"mixed"` when a corpus iteration used several).
    pub phipsi_path: &'static str,
    /// Seconds the worker grid sat without a live solve phase this
    /// iteration. Barrier alternation: the whole φ/ψ reduce + PGD span
    /// (the hidden synchronization cost this field makes visible).
    /// Pipelined: only the back-to-back `ComputeStats`/`ResumeSolve`
    /// broadcast, ~0. Always 0 on the teardown/sequential paths (no
    /// resident grid to keep busy).
    pub dict_wait_s: f64,
    /// Coordinate updates the grid accepted speculatively under the
    /// old dictionary while the PGD ran (pipelined alternation only;
    /// 0 under barrier and teardown).
    pub overlap_updates: u64,
}

/// CDL result.
#[derive(Clone, Debug)]
pub struct CdlResult {
    /// Learned dictionary `[K, P, L..]`.
    pub d: NdTensor,
    /// Final activations `[K, T'..]`.
    pub z: NdTensor,
    /// Fixed regularization used (from the initial dictionary).
    pub lambda: f64,
    pub trace: Vec<IterRecord>,
    pub converged: bool,
    pub runtime: f64,
    /// Worker-pool provenance when the persistent runtime served the
    /// run (`None` for the teardown modes).
    pub pool: Option<PoolReport>,
}

/// Learn a convolutional dictionary on observation `x`.
///
/// Thin wrapper: builds a one-shot [`crate::api::Session`] from the
/// config and fits. A persistent distributed backend spawns its pool,
/// serves the whole run, and shuts down when the one-shot session
/// drops — exactly the pre-facade behavior. Use a long-lived session
/// directly to keep the pool warm across calls.
pub fn learn_dictionary(x: &NdTensor, cfg: &CdlConfig) -> anyhow::Result<CdlResult> {
    crate::api::Session::from_cdl_config(cfg).fit_result(x)
}

/// Initial dictionary, the run's fixed regularization, and the engine
/// the lambda_max bootstrap built for `d0` (so the pool the caller
/// spawns can share the already-computed dictionary spectra). lambda is
/// fixed from the initial dictionary (as in the reference
/// implementation) so the objective is comparable across iterations.
pub(crate) fn prepare(
    x: &NdTensor,
    cfg: &CdlConfig,
) -> anyhow::Result<(NdTensor, f64, crate::conv::CorrEngine)> {
    let d = init_dictionary(x, cfg.n_atoms, &cfg.atom_dims, cfg.init, cfg.seed);
    let corr = crate::conv::CorrEngine::new(d.clone());
    let lambda = cfg.lambda_frac * corr.correlate_dict(x).norm_inf();
    anyhow::ensure!(lambda > 0.0, "degenerate workload: lambda_max = 0");
    Ok((d, lambda, corr))
}

/// Persistent-pool alternation on an already-running pool: never
/// gathers mid-run, leaves the pool alive for the caller (the session
/// keeps it resident; a one-shot caller drops it right after).
///
/// The pool must already hold the problem `(X, d, lambda)`; its
/// resident Z (zero on a fresh spawn, the previous activations on a
/// reused pool) is the alternation's warm start.
pub(crate) fn learn_on_pool(
    pool: &mut WorkerPool,
    x: &NdTensor,
    cfg: &CdlConfig,
    mut d: NdTensor,
    lambda: f64,
    start: Instant,
) -> anyhow::Result<CdlResult> {
    if pool.config().alternation == Alternation::Pipelined {
        return learn_on_pool_pipelined(pool, x, cfg, d, lambda, start);
    }
    let x_shared = pool.problem().x_shared();

    let mut trace: Vec<IterRecord> = Vec::new();
    let mut converged = false;

    for it in 0..cfg.max_iter {
        // ---- CSC step: workers warm-restart from their resident Z -------
        let t0 = Instant::now();
        let phase = pool.solve();
        anyhow::ensure!(
            !phase.diverged,
            "distributed CSC diverged at outer iteration {it} \
             (divergence guard tripped; resident Z is unusable)"
        );
        let csc_time = t0.elapsed().as_secs_f64();

        // ---- dictionary step: φ/ψ reduced from worker partials ----------
        let t1 = Instant::now();
        let (stats, z_nnz) = pool.compute_stats();
        let cost_after_csc = cost_from_stats(&stats, &d, lambda);
        let pgd = update_dict(&stats, &d, lambda, &cfg.dict_cfg);
        d = pgd.d;
        // Resample unused atoms from residual patches. Dead atoms are
        // detected signal-free from the phi diagonal (phi[k,k][tau=0] =
        // sum_u Z_k[u]^2); only when one actually died does the driver
        // pay a mid-run gather for the residual patches.
        let dead = dead_atoms_from_phi(&stats.phi);
        if !dead.is_empty() {
            let z = pool.gather();
            resample_dead_atoms(x, &z, &mut d, cfg.seed.wrapping_add(it as u64));
        }
        let dict_time = t1.elapsed().as_secs_f64();

        let rec = IterRecord {
            iter: it,
            cost: pgd.cost,
            cost_after_csc,
            z_nnz,
            csc_time,
            dict_time,
            elapsed: start.elapsed().as_secs_f64(),
            phipsi_path: "worker-partials",
            // Barrier alternation: the grid idles for the whole
            // dictionary step.
            dict_wait_s: dict_time,
            overlap_updates: 0,
        };
        if cfg.verbose {
            log_iter(&rec);
        }
        let prev_cost = trace.last().map(|r: &IterRecord| r.cost);
        trace.push(rec);

        if let Some(prev) = prev_cost {
            let cur = trace.last().unwrap().cost;
            if (prev - cur).abs() / prev.abs().max(1e-300) < cfg.nu {
                converged = true;
            }
        }
        if converged || it + 1 == cfg.max_iter {
            break;
        }
        // ---- broadcast the new dictionary; workers re-bootstrap beta
        //      warm from the Z they already hold ------------------------
        pool.set_dict(Arc::new(CscProblem::new(x_shared.clone(), d.clone(), lambda)));
    }

    // The single full-Z centralization of the run. The pool itself
    // stays up — the owning session decides when it dies.
    let z = pool.gather();
    let report = pool.report();

    Ok(CdlResult {
        d,
        z,
        lambda,
        trace,
        converged,
        runtime: start.elapsed().as_secs_f64(),
        pool: Some(report),
    })
}

/// Pipelined alternation on a resident pool
/// (`cfg.alternation == Pipelined`): the dictionary PGD overlaps the
/// next solve phase instead of stalling the grid.
///
/// Iteration 0's CSC step is a plain solve phase; every later
/// iteration's CSC step *is* the resumed phase the previous leg
/// supervised to convergence under its new dictionary
/// ([`WorkerPool::solve_overlapped`]). The `update` closure runs the
/// cost bookkeeping + PGD while the grid keeps iterating speculatively
/// under the old dictionary, and returns the rebuilt problem to land
/// mid-solve — or `None` on the final iteration, on `nu`-convergence,
/// or when an atom died (the dead-atom resample needs a mid-run gather,
/// so that iteration falls back to barrier semantics: retire the
/// speculative phase, gather, resample, `set_dict` between phases).
fn learn_on_pool_pipelined(
    pool: &mut WorkerPool,
    x: &NdTensor,
    cfg: &CdlConfig,
    mut d: NdTensor,
    lambda: f64,
    start: Instant,
) -> anyhow::Result<CdlResult> {
    let x_shared = pool.problem().x_shared();

    let mut trace: Vec<IterRecord> = Vec::new();
    let mut converged = false;
    let mut prev_overlap = pool.aggregate_stats().overlap_updates;

    let mut phase = pool.solve();
    let mut csc_time = phase.runtime;

    for it in 0..cfg.max_iter {
        anyhow::ensure!(
            !phase.diverged,
            "distributed CSC diverged at outer iteration {it} \
             (divergence guard tripped; resident Z is unusable)"
        );

        let prev_cost = trace.last().map(|r: &IterRecord| r.cost);
        let last = it + 1 == cfg.max_iter;
        let leg = pool.solve_overlapped(|stats, _z_nnz| {
            let t1 = Instant::now();
            let cost_after_csc = cost_from_stats(stats, &d, lambda);
            let pgd = update_dict(stats, &d, lambda, &cfg.dict_cfg);
            let dead = dead_atoms_from_phi(&stats.phi);
            let conv = prev_cost
                .is_some_and(|prev| (prev - pgd.cost).abs() / prev.abs().max(1e-300) < cfg.nu);
            let next = if dead.is_empty() && !conv && !last {
                Some(Arc::new(CscProblem::new(x_shared.clone(), pgd.d.clone(), lambda)))
            } else {
                // Converged / final / dead-atom iteration: retire the
                // speculative phase instead of landing a dictionary the
                // run won't solve under (the extra speculative updates
                // were ordinary warm progress under the old dictionary).
                None
            };
            (next, (pgd, cost_after_csc, dead, conv, t1.elapsed().as_secs_f64()))
        });
        let (pgd, cost_after_csc, dead, conv, mut dict_time) = leg.carry;
        d = pgd.d;
        if !dead.is_empty() {
            // Dead-atom fallback (barrier semantics for this iteration):
            // the speculative phase was already retired by the leg; pay
            // the mid-run gather and resample from residual patches.
            let t2 = Instant::now();
            let z = pool.gather();
            resample_dead_atoms(x, &z, &mut d, cfg.seed.wrapping_add(it as u64));
            dict_time += t2.elapsed().as_secs_f64();
        }

        let agg_overlap = pool.aggregate_stats().overlap_updates;
        let rec = IterRecord {
            iter: it,
            cost: pgd.cost,
            cost_after_csc,
            z_nnz: leg.z_nnz,
            csc_time,
            dict_time,
            elapsed: start.elapsed().as_secs_f64(),
            phipsi_path: "worker-partials",
            dict_wait_s: leg.dict_wait_s,
            overlap_updates: agg_overlap - prev_overlap,
        };
        prev_overlap = agg_overlap;
        if cfg.verbose {
            log_iter(&rec);
        }
        trace.push(rec);
        if conv {
            converged = true;
        }
        if converged || last {
            break;
        }

        if dead.is_empty() {
            // The leg landed the new dictionary mid-solve and supervised
            // the resumed phase to convergence under it: that phase is
            // iteration it+1's CSC step.
            phase = leg.phase;
        } else {
            pool.set_dict(Arc::new(CscProblem::new(x_shared.clone(), d.clone(), lambda)));
            phase = pool.solve();
        }
        csc_time = phase.runtime;
    }

    let z = pool.gather();
    let report = pool.report();

    Ok(CdlResult {
        d,
        z,
        lambda,
        trace,
        converged,
        runtime: start.elapsed().as_secs_f64(),
        pool: Some(report),
    })
}

/// Teardown alternation: rebuild the problem each iteration (X shared
/// via `Arc`) and warm-start the sparse coder from the previous Z.
pub(crate) fn learn_teardown(
    x: &NdTensor,
    cfg: &CdlConfig,
    mut d: NdTensor,
    lambda: f64,
    start: Instant,
) -> anyhow::Result<CdlResult> {
    let x_shared = Arc::new(x.clone());
    let mut z_prev: Option<NdTensor> = None;
    let mut trace: Vec<IterRecord> = Vec::new();
    let mut converged = false;

    for it in 0..cfg.max_iter {
        // ---- CSC step -----------------------------------------------------
        let t0 = Instant::now();
        let problem = CscProblem::new(x_shared.clone(), d.clone(), lambda);
        let z = match &cfg.csc {
            CscBackend::Sequential => {
                let r = solve_cd_warm(
                    &problem,
                    &CdConfig {
                        strategy: Strategy::LocallyGreedy,
                        tol: cfg.csc_tol,
                        seed: cfg.seed,
                        ..Default::default()
                    },
                    z_prev.as_ref(),
                );
                r.z
            }
            // The facade routes `Persistent` (and persistent
            // `Distributed`) to the resident-pool driver before ever
            // reaching here; the arm keeps the match total.
            CscBackend::Distributed(dcfg) | CscBackend::Persistent(dcfg) => {
                let mut dcfg = dcfg.clone();
                dcfg.tol = cfg.csc_tol;
                solve_distributed_warm(&problem, &dcfg, z_prev.as_ref()).z
            }
        };
        let csc_time = t0.elapsed().as_secs_f64();
        let cost_after_csc = problem.cost(&z);

        // ---- dictionary step ----------------------------------------------
        let t1 = Instant::now();
        let (stats, phipsi_path) =
            compute_stats_with_engine(&z, x, &cfg.atom_dims, cfg.stat_workers, &problem.corr);
        let pgd = update_dict(&stats, &d, lambda, &cfg.dict_cfg);
        d = pgd.d;
        // Resample unused atoms from residual patches (as the reference
        // implementation does): an atom with zero activation mass has a
        // zero gradient and would stay dead forever otherwise.
        resample_dead_atoms(x, &z, &mut d, cfg.seed.wrapping_add(it as u64));
        let dict_time = t1.elapsed().as_secs_f64();

        let rec = IterRecord {
            iter: it,
            cost: pgd.cost,
            cost_after_csc,
            z_nnz: z.nnz(),
            csc_time,
            dict_time,
            elapsed: start.elapsed().as_secs_f64(),
            phipsi_path,
            dict_wait_s: 0.0,
            overlap_updates: 0,
        };
        if cfg.verbose {
            log_iter(&rec);
        }
        let prev_cost = trace.last().map(|r: &IterRecord| r.cost);
        trace.push(rec);
        z_prev = Some(z);

        if let Some(prev) = prev_cost {
            let cur = trace.last().unwrap().cost;
            if (prev - cur).abs() / prev.abs().max(1e-300) < cfg.nu {
                converged = true;
                break;
            }
        }
    }

    Ok(CdlResult {
        d,
        z: z_prev.unwrap_or_else(|| NdTensor::zeros(&[cfg.n_atoms, 1])),
        lambda,
        trace,
        converged,
        runtime: start.elapsed().as_secs_f64(),
        pool: None,
    })
}

pub(crate) fn log_iter(rec: &IterRecord) {
    crate::log_info!(
        "cdl",
        "iter {:3}  cost {:.6e}  (csc {:.6e})  nnz {}  csc {:.2}s dict {:.2}s  phi/psi {}",
        rec.iter,
        rec.cost,
        rec.cost_after_csc,
        rec.z_nnz,
        rec.csc_time,
        rec.dict_time,
        rec.phipsi_path
    );
}

/// Atoms with zero activation mass, detected from the phi diagonal:
/// `phi[k,k][tau = 0] = sum_u Z_k[u]^2` is zero iff `Z_k` is
/// identically zero (a sum of squares cannot cancel).
fn dead_atoms_from_phi(phi: &NdTensor) -> Vec<usize> {
    let k_tot = phi.dims()[0];
    let cc_dims: Vec<usize> = phi.dims()[2..].to_vec();
    let cc_sp: usize = cc_dims.iter().product();
    let cc_str = crate::tensor::shape::strides_of(&cc_dims);
    // tau = 0 sits at index (L - 1) = (cc_dim - 1) / 2 per axis.
    let center: usize = cc_dims
        .iter()
        .zip(&cc_str)
        .map(|(n, s)| ((n - 1) / 2) * s)
        .sum();
    (0..k_tot)
        .filter(|&k| phi.data()[(k * k_tot + k) * cc_sp + center] == 0.0)
        .collect()
}

/// Replace atoms whose activation mass is zero with normalized random
/// patches of the current residual (where un-modelled structure lives).
fn resample_dead_atoms(x: &NdTensor, z: &NdTensor, d: &mut NdTensor, seed: u64) {
    let k_tot = d.dims()[0];
    let sp: usize = z.dims()[1..].iter().product();
    let dead: Vec<usize> = (0..k_tot)
        .filter(|&k| z.data()[k * sp..(k + 1) * sp].iter().all(|v| *v == 0.0))
        .collect();
    if dead.is_empty() {
        return;
    }
    let resid = x.sub(&crate::conv::reconstruct(z, d));
    let atom_dims: Vec<usize> = d.dims()[2..].to_vec();
    let fresh = crate::cdl::init::init_dictionary(
        &resid,
        dead.len(),
        &atom_dims,
        crate::cdl::init::InitStrategy::RandomPatches,
        seed,
    );
    let atom_len: usize = d.dims()[1..].iter().product();
    for (i, &k) in dead.iter().enumerate() {
        d.slice0_mut(k).copy_from_slice(&fresh.data()[i * atom_len..(i + 1) * atom_len]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{best_atom_correlation, SyntheticConfig};

    #[test]
    fn cdl_cost_decreases_1d() {
        let w = SyntheticConfig::signal_1d(400, 3, 8).generate(1);
        let cfg = CdlConfig {
            n_atoms: 3,
            atom_dims: vec![8],
            max_iter: 8,
            csc_tol: 1e-4,
            seed: 1,
            ..Default::default()
        };
        let r = learn_dictionary(&w.x, &cfg).unwrap();
        assert!(r.trace.len() >= 2);
        // The alternation is monotone (up to CSC warm-start tolerance).
        for pair in r.trace.windows(2) {
            assert!(
                pair[1].cost <= pair[0].cost * (1.0 + 1e-6) + 1e-9,
                "cost increased: {} -> {}",
                pair[0].cost,
                pair[1].cost
            );
        }
        // And within each iteration the dict update improves on the CSC cost.
        for rec in &r.trace {
            assert!(rec.cost <= rec.cost_after_csc + 1e-9);
        }
    }

    #[test]
    fn cdl_recovers_planted_atoms() {
        // Moderate-size planted problem: at least one learned atom should
        // align well with a ground-truth atom.
        let mut gen = SyntheticConfig::signal_1d(2500, 2, 8);
        gen.rho = 0.02;
        gen.noise_std = 0.01;
        let w = gen.generate(3);
        let cfg = CdlConfig {
            n_atoms: 2,
            atom_dims: vec![8],
            max_iter: 30,
            csc_tol: 1e-6,
            lambda_frac: 0.03,
            seed: 3,
            ..Default::default()
        };
        let r = learn_dictionary(&w.x, &cfg).unwrap();
        let c0 = best_atom_correlation(r.d.slice0(0), &w.d_true, &[8]);
        let c1 = best_atom_correlation(r.d.slice0(1), &w.d_true, &[8]);
        assert!(
            c0.max(c1) > 0.9,
            "no learned atom matches ground truth: {c0:.3}, {c1:.3}"
        );
    }

    #[test]
    fn cdl_2d_runs_and_decreases() {
        let w = SyntheticConfig::image_2d(32, 32, 2, 5).generate(5);
        let cfg = CdlConfig {
            n_atoms: 2,
            atom_dims: vec![5, 5],
            max_iter: 4,
            csc_tol: 1e-3,
            seed: 5,
            ..Default::default()
        };
        let r = learn_dictionary(&w.x, &cfg).unwrap();
        assert!(r.trace.last().unwrap().cost <= r.trace.first().unwrap().cost);
    }

    #[test]
    fn dead_atoms_are_resampled() {
        // Plant an all-zero activation atom; after one driver iteration
        // the atom must have been replaced by a (normalized) patch.
        let w = SyntheticConfig::signal_1d(300, 2, 6).generate(11);
        let z = NdTensor::zeros(&[3, 295]);
        let mut d = crate::cdl::init::init_dictionary(
            &w.x,
            3,
            &[6],
            crate::cdl::init::InitStrategy::Gaussian,
            11,
        );
        let before = d.slice0(1).to_vec();
        resample_dead_atoms(&w.x, &z, &mut d, 1);
        let after = d.slice0(1);
        assert_ne!(before, after, "dead atom should be resampled");
        let n: f64 = after.iter().map(|v| v * v).sum();
        assert!((n - 1.0).abs() < 1e-9);
    }

    #[test]
    fn dead_atom_detection_from_phi_matches_z() {
        let mut z = NdTensor::zeros(&[3, 40]);
        *z.at_mut(&[0, 5]) = 1.0;
        *z.at_mut(&[2, 20]) = -2.0; // atom 1 stays dead
        let phi = crate::conv::compute_phi(&z, &[6]);
        assert_eq!(dead_atoms_from_phi(&phi), vec![1]);
        let phi2d = crate::conv::compute_phi(&NdTensor::zeros(&[2, 10, 10]), &[3, 3]);
        assert_eq!(dead_atoms_from_phi(&phi2d), vec![0, 1]);
    }

    #[test]
    fn cdl_with_distributed_backend() {
        let w = SyntheticConfig::signal_1d(300, 2, 6).generate(7);
        let cfg = CdlConfig {
            n_atoms: 2,
            atom_dims: vec![6],
            max_iter: 3,
            csc_tol: 1e-3,
            csc: CscBackend::Distributed(DicodConfig::dicodile(2)),
            seed: 7,
            ..Default::default()
        };
        let r = learn_dictionary(&w.x, &cfg).unwrap();
        assert!(r.trace.last().unwrap().cost <= r.trace.first().unwrap().cost * (1.0 + 1e-9));
        // dicodile() defaults to the resident pool: provenance recorded,
        // workers spawned exactly once.
        let report = r.pool.expect("persistent run records pool provenance");
        assert_eq!(report.workers_spawned, report.n_workers);
        for rec in &r.trace {
            assert_eq!(rec.phipsi_path, "worker-partials");
        }
    }

    #[test]
    fn cdl_with_teardown_distributed_backend() {
        let w = SyntheticConfig::signal_1d(300, 2, 6).generate(7);
        let cfg = CdlConfig {
            n_atoms: 2,
            atom_dims: vec![6],
            max_iter: 3,
            csc_tol: 1e-3,
            csc: CscBackend::Distributed(DicodConfig {
                persistent: false,
                ..DicodConfig::dicodile(2)
            }),
            seed: 7,
            ..Default::default()
        };
        let r = learn_dictionary(&w.x, &cfg).unwrap();
        assert!(r.trace.last().unwrap().cost <= r.trace.first().unwrap().cost * (1.0 + 1e-9));
        assert!(r.pool.is_none());
    }
}
