//! The full CDL alternating-minimization driver (Algorithm 2):
//!
//! ```text
//! repeat
//!   Z <- DiCoDiLe-Z(X, D, W)                (or sequential LGCD)
//!   (phi, psi) <- map-reduce over W workers (eq. 17)
//!   D <- PGD with Armijo line search
//! until cost variation < nu
//! ```

use std::time::Instant;

use crate::cdl::init::{init_dictionary, InitStrategy};
use crate::csc::cd::{solve_cd_warm, CdConfig};
use crate::csc::problem::CscProblem;
use crate::csc::select::Strategy;
use crate::dicod::config::DicodConfig;
use crate::dicod::coordinator::solve_distributed;
use crate::dict::pgd::{update_dict, PgdConfig};
use crate::dict::phi_psi::compute_stats_parallel;
use crate::tensor::NdTensor;

/// Which sparse coder the CDL loop uses.
#[derive(Clone, Debug)]
pub enum CscBackend {
    /// Sequential LGCD (warm-started between outer iterations).
    Sequential,
    /// DiCoDiLe-Z with the given worker configuration.
    Distributed(DicodConfig),
}

/// CDL driver configuration.
#[derive(Clone, Debug)]
pub struct CdlConfig {
    pub n_atoms: usize,
    pub atom_dims: Vec<usize>,
    /// `lambda = lambda_frac * lambda_max(X, D_0)`.
    pub lambda_frac: f64,
    /// Outer alternations.
    pub max_iter: usize,
    /// Stop when the relative cost variation drops below `nu`.
    pub nu: f64,
    pub csc: CscBackend,
    pub csc_tol: f64,
    pub dict_cfg: PgdConfig,
    pub init: InitStrategy,
    /// Threads for the phi/psi map-reduce.
    pub stat_workers: usize,
    pub seed: u64,
    /// Print per-iteration progress to stderr.
    pub verbose: bool,
}

impl Default for CdlConfig {
    fn default() -> Self {
        CdlConfig {
            n_atoms: 5,
            atom_dims: vec![16],
            lambda_frac: 0.1,
            max_iter: 30,
            nu: 1e-5,
            csc: CscBackend::Sequential,
            csc_tol: 1e-4,
            dict_cfg: PgdConfig::default(),
            init: InitStrategy::RandomPatches,
            stat_workers: 4,
            seed: 0,
            verbose: false,
        }
    }
}

/// One outer-iteration record.
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    /// Objective after the dictionary update.
    pub cost: f64,
    /// Objective after the CSC step (before the dict update).
    pub cost_after_csc: f64,
    pub z_nnz: usize,
    pub csc_time: f64,
    pub dict_time: f64,
    pub elapsed: f64,
}

/// CDL result.
#[derive(Clone, Debug)]
pub struct CdlResult {
    /// Learned dictionary `[K, P, L..]`.
    pub d: NdTensor,
    /// Final activations `[K, T'..]`.
    pub z: NdTensor,
    /// Fixed regularization used (from the initial dictionary).
    pub lambda: f64,
    pub trace: Vec<IterRecord>,
    pub converged: bool,
    pub runtime: f64,
}

/// Learn a convolutional dictionary on observation `x`.
pub fn learn_dictionary(x: &NdTensor, cfg: &CdlConfig) -> anyhow::Result<CdlResult> {
    let start = Instant::now();
    let mut d = init_dictionary(x, cfg.n_atoms, &cfg.atom_dims, cfg.init, cfg.seed);
    // lambda is fixed from the initial dictionary (as in the reference
    // implementation) so the objective is comparable across iterations.
    let lambda = cfg.lambda_frac * crate::csc::problem::lambda_max(x, &d);
    anyhow::ensure!(lambda > 0.0, "degenerate workload: lambda_max = 0");

    let mut z_prev: Option<NdTensor> = None;
    let mut trace: Vec<IterRecord> = Vec::new();
    let mut converged = false;

    for it in 0..cfg.max_iter {
        // ---- CSC step -----------------------------------------------------
        let t0 = Instant::now();
        let problem = CscProblem::new(x.clone(), d.clone(), lambda);
        let z = match &cfg.csc {
            CscBackend::Sequential => {
                let r = solve_cd_warm(
                    &problem,
                    &CdConfig {
                        strategy: Strategy::LocallyGreedy,
                        tol: cfg.csc_tol,
                        seed: cfg.seed,
                        ..Default::default()
                    },
                    z_prev.as_ref(),
                );
                r.z
            }
            CscBackend::Distributed(dcfg) => {
                let mut dcfg = dcfg.clone();
                dcfg.tol = cfg.csc_tol;
                solve_distributed(&problem, &dcfg).z
            }
        };
        let csc_time = t0.elapsed().as_secs_f64();
        let cost_after_csc = problem.cost(&z);

        // ---- dictionary step ----------------------------------------------
        let t1 = Instant::now();
        let stats = compute_stats_parallel(&z, x, &cfg.atom_dims, cfg.stat_workers);
        let pgd = update_dict(&stats, &d, lambda, &cfg.dict_cfg);
        d = pgd.d;
        // Resample unused atoms from residual patches (as the reference
        // implementation does): an atom with zero activation mass has a
        // zero gradient and would stay dead forever otherwise.
        resample_dead_atoms(x, &z, &mut d, cfg.seed.wrapping_add(it as u64));
        let dict_time = t1.elapsed().as_secs_f64();

        let rec = IterRecord {
            iter: it,
            cost: pgd.cost,
            cost_after_csc,
            z_nnz: z.nnz(),
            csc_time,
            dict_time,
            elapsed: start.elapsed().as_secs_f64(),
        };
        if cfg.verbose {
            crate::log_info!(
                "cdl",
                "iter {:3}  cost {:.6e}  (csc {:.6e})  nnz {}  csc {:.2}s dict {:.2}s",
                rec.iter,
                rec.cost,
                rec.cost_after_csc,
                rec.z_nnz,
                rec.csc_time,
                rec.dict_time
            );
        }
        let prev_cost = trace.last().map(|r: &IterRecord| r.cost);
        trace.push(rec);
        z_prev = Some(z);

        if let Some(prev) = prev_cost {
            let cur = trace.last().unwrap().cost;
            if (prev - cur).abs() / prev.abs().max(1e-300) < cfg.nu {
                converged = true;
                break;
            }
        }
    }

    Ok(CdlResult {
        d,
        z: z_prev.unwrap_or_else(|| NdTensor::zeros(&[cfg.n_atoms, 1])),
        lambda,
        trace,
        converged,
        runtime: start.elapsed().as_secs_f64(),
    })
}

/// Replace atoms whose activation mass is zero with normalized random
/// patches of the current residual (where un-modelled structure lives).
fn resample_dead_atoms(x: &NdTensor, z: &NdTensor, d: &mut NdTensor, seed: u64) {
    let k_tot = d.dims()[0];
    let sp: usize = z.dims()[1..].iter().product();
    let dead: Vec<usize> = (0..k_tot)
        .filter(|&k| z.data()[k * sp..(k + 1) * sp].iter().all(|v| *v == 0.0))
        .collect();
    if dead.is_empty() {
        return;
    }
    let resid = x.sub(&crate::conv::reconstruct(z, d));
    let atom_dims: Vec<usize> = d.dims()[2..].to_vec();
    let fresh = crate::cdl::init::init_dictionary(
        &resid,
        dead.len(),
        &atom_dims,
        crate::cdl::init::InitStrategy::RandomPatches,
        seed,
    );
    let atom_len: usize = d.dims()[1..].iter().product();
    for (i, &k) in dead.iter().enumerate() {
        d.slice0_mut(k).copy_from_slice(&fresh.data()[i * atom_len..(i + 1) * atom_len]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{best_atom_correlation, SyntheticConfig};

    #[test]
    fn cdl_cost_decreases_1d() {
        let w = SyntheticConfig::signal_1d(400, 3, 8).generate(1);
        let cfg = CdlConfig {
            n_atoms: 3,
            atom_dims: vec![8],
            max_iter: 8,
            csc_tol: 1e-4,
            seed: 1,
            ..Default::default()
        };
        let r = learn_dictionary(&w.x, &cfg).unwrap();
        assert!(r.trace.len() >= 2);
        // The alternation is monotone (up to CSC warm-start tolerance).
        for pair in r.trace.windows(2) {
            assert!(
                pair[1].cost <= pair[0].cost * (1.0 + 1e-6) + 1e-9,
                "cost increased: {} -> {}",
                pair[0].cost,
                pair[1].cost
            );
        }
        // And within each iteration the dict update improves on the CSC cost.
        for rec in &r.trace {
            assert!(rec.cost <= rec.cost_after_csc + 1e-9);
        }
    }

    #[test]
    fn cdl_recovers_planted_atoms() {
        // Moderate-size planted problem: at least one learned atom should
        // align well with a ground-truth atom.
        let mut gen = SyntheticConfig::signal_1d(2500, 2, 8);
        gen.rho = 0.02;
        gen.noise_std = 0.01;
        let w = gen.generate(3);
        let cfg = CdlConfig {
            n_atoms: 2,
            atom_dims: vec![8],
            max_iter: 30,
            csc_tol: 1e-6,
            lambda_frac: 0.03,
            seed: 3,
            ..Default::default()
        };
        let r = learn_dictionary(&w.x, &cfg).unwrap();
        let c0 = best_atom_correlation(r.d.slice0(0), &w.d_true, &[8]);
        let c1 = best_atom_correlation(r.d.slice0(1), &w.d_true, &[8]);
        assert!(
            c0.max(c1) > 0.9,
            "no learned atom matches ground truth: {c0:.3}, {c1:.3}"
        );
    }

    #[test]
    fn cdl_2d_runs_and_decreases() {
        let w = SyntheticConfig::image_2d(32, 32, 2, 5).generate(5);
        let cfg = CdlConfig {
            n_atoms: 2,
            atom_dims: vec![5, 5],
            max_iter: 4,
            csc_tol: 1e-3,
            seed: 5,
            ..Default::default()
        };
        let r = learn_dictionary(&w.x, &cfg).unwrap();
        assert!(r.trace.last().unwrap().cost <= r.trace.first().unwrap().cost);
    }

    #[test]
    fn dead_atoms_are_resampled() {
        // Plant an all-zero activation atom; after one driver iteration
        // the atom must have been replaced by a (normalized) patch.
        let w = SyntheticConfig::signal_1d(300, 2, 6).generate(11);
        let z = NdTensor::zeros(&[3, 295]);
        let mut d = crate::cdl::init::init_dictionary(
            &w.x,
            3,
            &[6],
            crate::cdl::init::InitStrategy::Gaussian,
            11,
        );
        let before = d.slice0(1).to_vec();
        resample_dead_atoms(&w.x, &z, &mut d, 1);
        let after = d.slice0(1);
        assert_ne!(before, after, "dead atom should be resampled");
        let n: f64 = after.iter().map(|v| v * v).sum();
        assert!((n - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cdl_with_distributed_backend() {
        let w = SyntheticConfig::signal_1d(300, 2, 6).generate(7);
        let cfg = CdlConfig {
            n_atoms: 2,
            atom_dims: vec![6],
            max_iter: 3,
            csc_tol: 1e-3,
            csc: CscBackend::Distributed(DicodConfig::dicodile(2)),
            seed: 7,
            ..Default::default()
        };
        let r = learn_dictionary(&w.x, &cfg).unwrap();
        assert!(r.trace.last().unwrap().cost <= r.trace.first().unwrap().cost * (1.0 + 1e-9));
    }
}
