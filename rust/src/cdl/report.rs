//! Human- and machine-readable reports for CDL runs.

use crate::cdl::driver::CdlResult;
use crate::util::json::Json;

/// Render the iteration trace as an aligned text table.
pub fn trace_table(result: &CdlResult) -> String {
    let mut s = String::new();
    s.push_str("iter        cost   cost(csc)      nnz   csc[s]  dict[s]  wait[s]  phi/psi\n");
    for r in &result.trace {
        s.push_str(&format!(
            "{:4}  {:10.4e}  {:10.4e}  {:7}  {:7.3}  {:7.3}  {:7.3}  {}\n",
            r.iter,
            r.cost,
            r.cost_after_csc,
            r.z_nnz,
            r.csc_time,
            r.dict_time,
            r.dict_wait_s,
            r.phipsi_path
        ));
    }
    s
}

/// Serialize the run to JSON (for EXPERIMENTS.md provenance).
pub fn to_json(result: &CdlResult) -> Json {
    Json::obj(vec![
        ("lambda", Json::Num(result.lambda)),
        ("converged", Json::Bool(result.converged)),
        ("runtime", Json::Num(result.runtime)),
        // Residency + selection provenance of the persistent runtime:
        // `segments_skipped` / `segments_rescanned` record how much of
        // the workers' selection work the incremental dz_opt cache
        // answered in O(1) (skipped is 0 under DICODILE_SELECT=rescan).
        (
            "pool",
            match &result.pool {
                Some(p) => Json::obj(vec![
                    ("n_workers", Json::Num(p.n_workers as f64)),
                    ("workers_spawned", Json::Num(p.workers_spawned as f64)),
                    ("transport", Json::str(p.transport.name())),
                    ("iterations", Json::Num(p.stats.iterations as f64)),
                    ("updates", Json::Num(p.stats.updates as f64)),
                    ("msgs_sent", Json::Num(p.stats.msgs_sent as f64)),
                    ("soft_locked", Json::Num(p.stats.soft_locked as f64)),
                    ("work", Json::Num(p.stats.work as f64)),
                    ("segments_skipped", Json::Num(p.stats.segments_skipped as f64)),
                    ("segments_rescanned", Json::Num(p.stats.segments_rescanned as f64)),
                    ("dz_cache_filled", Json::Num(p.stats.dz_cache_filled as f64)),
                    ("spectra_bytes", Json::Num(p.spectra_bytes as f64)),
                    // Residency outcome: true iff the pool was shut
                    // down by the session's cost-weighted eviction
                    // policy rather than surviving to close().
                    ("evicted", Json::Bool(p.evicted)),
                ]),
                None => Json::Null,
            },
        ),
        (
            "trace",
            Json::Arr(
                result
                    .trace
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("iter", Json::Num(r.iter as f64)),
                            ("cost", Json::Num(r.cost)),
                            ("cost_after_csc", Json::Num(r.cost_after_csc)),
                            ("z_nnz", Json::Num(r.z_nnz as f64)),
                            ("csc_time", Json::Num(r.csc_time)),
                            ("dict_time", Json::Num(r.dict_time)),
                            // Alternation provenance: how long the grid
                            // sat idle for the dictionary step (~0 when
                            // pipelined) and how many coordinate updates
                            // it accepted speculatively meanwhile.
                            ("dict_wait_s", Json::Num(r.dict_wait_s)),
                            ("overlap_updates", Json::Num(r.overlap_updates as f64)),
                            ("elapsed", Json::Num(r.elapsed)),
                            ("phipsi", Json::str(r.phipsi_path)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Render learned atoms as a crude ASCII intensity chart (for terminal
/// inspection of 2-D atoms; one block per atom).
pub fn ascii_atoms(d: &crate::tensor::NdTensor, max_atoms: usize) -> String {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let k = d.dims()[0].min(max_atoms);
    let p = d.dims()[1];
    let sp: &[usize] = &d.dims()[2..];
    let mut out = String::new();
    if sp.len() != 2 {
        return format!("({}d atoms; ascii preview only for 2-d)\n", sp.len());
    }
    let (h, w) = (sp[0], sp[1]);
    for ki in 0..k {
        out.push_str(&format!("atom {ki}\n"));
        let a = d.slice0(ki);
        let lo = a.iter().cloned().fold(f64::MAX, f64::min);
        let hi = a.iter().cloned().fold(f64::MIN, f64::max);
        let scale = if hi > lo { (RAMP.len() - 1) as f64 / (hi - lo) } else { 0.0 };
        for i in 0..h {
            for j in 0..w {
                // average channels for display
                let mut v = 0.0;
                for pi in 0..p {
                    v += a[pi * h * w + i * w + j];
                }
                v /= p as f64;
                let idx = ((v - lo) * scale).round().clamp(0.0, (RAMP.len() - 1) as f64);
                out.push(RAMP[idx as usize] as char);
            }
            out.push('\n');
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cdl::driver::IterRecord;
    use crate::tensor::NdTensor;

    fn dummy_result() -> CdlResult {
        CdlResult {
            d: NdTensor::zeros(&[2, 1, 3, 3]),
            z: NdTensor::zeros(&[2, 4]),
            lambda: 0.5,
            trace: vec![IterRecord {
                iter: 0,
                cost: 10.0,
                cost_after_csc: 11.0,
                z_nnz: 7,
                csc_time: 0.1,
                dict_time: 0.2,
                elapsed: 0.3,
                phipsi_path: "sparse-seq",
                dict_wait_s: 0.2,
                overlap_updates: 0,
            }],
            converged: true,
            runtime: 0.3,
            pool: None,
        }
    }

    #[test]
    fn table_contains_rows() {
        let t = trace_table(&dummy_result());
        assert!(t.contains("iter"));
        assert!(t.lines().count() >= 2);
    }

    #[test]
    fn json_roundtrips() {
        let j = to_json(&dummy_result());
        let parsed = Json::parse(&j.dumps()).unwrap();
        assert_eq!(parsed.get("lambda").unwrap().as_f64(), Some(0.5));
        assert_eq!(parsed.get("trace").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(parsed.get("pool"), Some(&Json::Null));
    }

    #[test]
    fn json_records_pool_selection_counters() {
        use crate::dicod::messages::WorkerStats;
        use crate::dicod::pool::PoolReport;
        let mut r = dummy_result();
        let stats = WorkerStats {
            iterations: 100,
            updates: 40,
            segments_skipped: 60,
            segments_rescanned: 40,
            ..Default::default()
        };
        r.pool = Some(PoolReport {
            n_workers: 2,
            workers_spawned: 2,
            transport: crate::dicod::transport::TransportKind::Channel,
            stats: stats.clone(),
            per_worker: vec![stats.clone(), WorkerStats::default()],
            spectra_bytes: 1024,
            evicted: false,
        });
        let parsed = Json::parse(&to_json(&r).dumps()).unwrap();
        let pool = parsed.get("pool").unwrap();
        assert_eq!(pool.get("segments_skipped").unwrap().as_f64(), Some(60.0));
        assert_eq!(pool.get("segments_rescanned").unwrap().as_f64(), Some(40.0));
        assert_eq!(pool.get("n_workers").unwrap().as_f64(), Some(2.0));
        assert_eq!(pool.get("transport").unwrap().as_str(), Some("channel"));
        assert_eq!(pool.get("spectra_bytes").unwrap().as_f64(), Some(1024.0));
        assert_eq!(pool.get("evicted"), Some(&Json::Bool(false)));
    }

    #[test]
    fn ascii_preview_2d() {
        let mut d = NdTensor::zeros(&[1, 1, 3, 3]);
        *d.at_mut(&[0, 0, 1, 1]) = 1.0;
        let s = ascii_atoms(&d, 5);
        assert!(s.contains("atom 0"));
        assert!(s.contains('@'));
    }
}
