//! Batch CDL: learn one dictionary over a *collection* of observations.
//!
//! The paper's formulation is per-signal, but its sufficient-statistics
//! dictionary update (§4.2) extends directly to corpora: the objective
//! `sum_n 1/2 ||X_n - Z_n * D||^2 + lambda ||Z_n||_1` has
//! `phi = sum_n phi_n` and `psi = sum_n psi_n` as sufficient statistics,
//! so the dictionary step stays independent of both the signal sizes
//! and the corpus size. The CSC steps are embarrassingly parallel
//! across signals (each can itself be a DiCoDiLe-Z grid).

use std::sync::Arc;
use std::time::Instant;

use crate::cdl::driver::{CscBackend, IterRecord};
use crate::cdl::init::{init_dictionary, InitStrategy};
use crate::csc::cd::{solve_cd_warm, CdConfig};
use crate::csc::problem::CscProblem;
use crate::csc::select::Strategy;
use crate::dicod::coordinator::solve_distributed_warm;
use crate::dict::pgd::{update_dict, PgdConfig};
use crate::dict::phi_psi::{compute_stats_auto, DictStats};
use crate::tensor::NdTensor;

/// Batch CDL configuration (mirrors `CdlConfig` plus corpus handling).
#[derive(Clone, Debug)]
pub struct BatchCdlConfig {
    pub n_atoms: usize,
    pub atom_dims: Vec<usize>,
    /// `lambda = lambda_frac * max_n lambda_max(X_n, D_0)`.
    pub lambda_frac: f64,
    pub max_iter: usize,
    pub nu: f64,
    pub csc: CscBackend,
    pub csc_tol: f64,
    pub dict_cfg: PgdConfig,
    pub init: InitStrategy,
    pub stat_workers: usize,
    pub seed: u64,
}

impl Default for BatchCdlConfig {
    fn default() -> Self {
        BatchCdlConfig {
            n_atoms: 5,
            atom_dims: vec![16],
            lambda_frac: 0.1,
            max_iter: 20,
            nu: 1e-5,
            csc: CscBackend::Sequential,
            csc_tol: 1e-4,
            dict_cfg: PgdConfig::default(),
            init: InitStrategy::RandomPatches,
            stat_workers: 4,
            seed: 0,
        }
    }
}

/// Batch CDL result.
#[derive(Clone, Debug)]
pub struct BatchCdlResult {
    pub d: NdTensor,
    /// Final activations per signal.
    pub zs: Vec<NdTensor>,
    pub lambda: f64,
    /// Total-objective trace (summed over the corpus).
    pub trace: Vec<IterRecord>,
    pub converged: bool,
    pub runtime: f64,
}

/// Learn a dictionary over a corpus of observations (all with the same
/// channel count; spatial sizes may differ).
pub fn learn_dictionary_batch(
    xs: &[NdTensor],
    cfg: &BatchCdlConfig,
) -> anyhow::Result<BatchCdlResult> {
    anyhow::ensure!(!xs.is_empty(), "empty corpus");
    let p = xs[0].dims()[0];
    for (i, x) in xs.iter().enumerate() {
        anyhow::ensure!(
            x.dims()[0] == p,
            "signal {i} has {} channels, expected {p}",
            x.dims()[0]
        );
        anyhow::ensure!(
            x.dims().len() == cfg.atom_dims.len() + 1,
            "signal {i} rank mismatch"
        );
    }
    let start = Instant::now();
    // Initialize from the first signal's patches.
    let mut d = init_dictionary(&xs[0], cfg.n_atoms, &cfg.atom_dims, cfg.init, cfg.seed);
    // One engine for the whole corpus: the lambda_max bootstraps share
    // the dictionary spectra instead of rebuilding them per signal.
    let corr = crate::conv::CorrEngine::new(d.clone());
    let lambda = cfg.lambda_frac
        * xs.iter()
            .map(|x| corr.correlate_dict(x).norm_inf())
            .fold(0.0f64, f64::max);
    anyhow::ensure!(lambda > 0.0, "degenerate corpus: lambda_max = 0");

    // Share each observation once; per-iteration problems reuse the
    // Arcs instead of recloning the corpus.
    let xs_shared: Vec<Arc<NdTensor>> = xs.iter().map(|x| Arc::new(x.clone())).collect();
    let mut zs: Vec<Option<NdTensor>> = vec![None; xs.len()];
    let mut trace: Vec<IterRecord> = Vec::new();
    let mut converged = false;

    for it in 0..cfg.max_iter {
        // ---- CSC per signal -------------------------------------------------
        let t0 = Instant::now();
        let mut cost_after_csc = 0.0;
        let mut nnz = 0usize;
        for (x, z_slot) in xs_shared.iter().zip(zs.iter_mut()) {
            let problem = CscProblem::new(x.clone(), d.clone(), lambda);
            let z = match &cfg.csc {
                CscBackend::Sequential => {
                    solve_cd_warm(
                        &problem,
                        &CdConfig {
                            strategy: Strategy::LocallyGreedy,
                            tol: cfg.csc_tol,
                            seed: cfg.seed,
                            ..Default::default()
                        },
                        z_slot.as_ref(),
                    )
                    .z
                }
                // The corpus loop does not hold per-signal resident
                // pools yet (a ROADMAP follow-up): both distributed
                // variants run one temporary pool per signal, but each
                // is warm-started from that signal's previous
                // activations, so converged coordinates still carry
                // over between outer iterations.
                CscBackend::Distributed(dcfg) | CscBackend::Persistent(dcfg) => {
                    let mut dcfg = dcfg.clone();
                    dcfg.tol = cfg.csc_tol;
                    solve_distributed_warm(&problem, &dcfg, z_slot.as_ref()).z
                }
            };
            cost_after_csc += problem.cost(&z);
            nnz += z.nnz();
            *z_slot = Some(z);
        }
        let csc_time = t0.elapsed().as_secs_f64();

        // ---- summed statistics + one dictionary update ----------------------
        let t1 = Instant::now();
        let mut agg: Option<DictStats> = None;
        let mut phipsi_path: Option<&'static str> = None;
        for (x, z) in xs.iter().zip(&zs) {
            let (s, path) = compute_stats_auto(
                z.as_ref().unwrap(),
                x,
                &cfg.atom_dims,
                cfg.stat_workers,
            );
            phipsi_path = Some(match phipsi_path {
                None => path,
                Some(prev) if prev == path => path,
                Some(_) => "mixed",
            });
            agg = Some(match agg {
                None => s,
                Some(mut a) => {
                    a.phi.add_assign(&s.phi);
                    a.psi.add_assign(&s.psi);
                    a.x_norm_sq += s.x_norm_sq;
                    a.z_l1 += s.z_l1;
                    a
                }
            });
        }
        let stats = agg.unwrap();
        let pgd = update_dict(&stats, &d, lambda, &cfg.dict_cfg);
        d = pgd.d;
        let dict_time = t1.elapsed().as_secs_f64();

        let rec = IterRecord {
            iter: it,
            cost: pgd.cost,
            cost_after_csc,
            z_nnz: nnz,
            csc_time,
            dict_time,
            elapsed: start.elapsed().as_secs_f64(),
            phipsi_path: phipsi_path.unwrap_or("sparse-seq"),
        };
        let prev = trace.last().map(|r| r.cost);
        trace.push(rec);
        if let Some(prev) = prev {
            let cur = trace.last().unwrap().cost;
            if (prev - cur).abs() / prev.abs().max(1e-300) < cfg.nu {
                converged = true;
                break;
            }
        }
    }

    Ok(BatchCdlResult {
        d,
        zs: zs.into_iter().map(|z| z.unwrap()).collect(),
        lambda,
        trace,
        converged,
        runtime: start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{best_atom_correlation, SyntheticConfig};

    fn corpus(n: usize, seed: u64) -> (Vec<NdTensor>, NdTensor) {
        // Signals sharing one ground-truth dictionary.
        let mut gen = SyntheticConfig::signal_1d(500, 2, 8);
        gen.rho = 0.02;
        gen.noise_std = 0.02;
        let w0 = gen.generate(seed);
        let d_true = w0.d_true.clone();
        let mut xs = vec![w0.x];
        for i in 1..n {
            let mut rng = crate::util::rng::Pcg64::seeded(seed + 1000 + i as u64);
            let mut z = NdTensor::zeros(&[2, 493]);
            for v in z.data_mut().iter_mut() {
                if rng.bernoulli(0.02) {
                    *v = rng.normal_ms(0.0, 5.0);
                }
            }
            let clean = crate::conv::reconstruct(&z, &d_true);
            let noise = NdTensor::from_vec(clean.dims(), rng.normal_vec(clean.len())).scale(0.02);
            xs.push(clean.add(&noise));
        }
        (xs, d_true)
    }

    #[test]
    fn batch_cost_decreases() {
        let (xs, _) = corpus(3, 1);
        let cfg = BatchCdlConfig {
            n_atoms: 2,
            atom_dims: vec![8],
            max_iter: 6,
            seed: 1,
            ..Default::default()
        };
        let r = learn_dictionary_batch(&xs, &cfg).unwrap();
        assert!(r.trace.len() >= 2);
        for w in r.trace.windows(2) {
            assert!(w[1].cost <= w[0].cost * (1.0 + 1e-6) + 1e-9);
        }
        assert_eq!(r.zs.len(), 3);
    }

    #[test]
    fn batch_recovers_shared_dictionary() {
        let (xs, d_true) = corpus(4, 3);
        let cfg = BatchCdlConfig {
            n_atoms: 2,
            atom_dims: vec![8],
            max_iter: 20,
            lambda_frac: 0.03,
            csc_tol: 1e-5,
            seed: 3,
            ..Default::default()
        };
        let r = learn_dictionary_batch(&xs, &cfg).unwrap();
        let c0 = best_atom_correlation(r.d.slice0(0), &d_true, &[8]);
        let c1 = best_atom_correlation(r.d.slice0(1), &d_true, &[8]);
        assert!(c0.max(c1) > 0.9, "batch recovery failed: {c0:.3} {c1:.3}");
    }

    #[test]
    fn batch_rejects_bad_corpus() {
        assert!(learn_dictionary_batch(&[], &BatchCdlConfig::default()).is_err());
        let a = NdTensor::zeros(&[1, 50]);
        let b = NdTensor::zeros(&[2, 50]);
        let cfg = BatchCdlConfig { atom_dims: vec![8], ..Default::default() };
        assert!(learn_dictionary_batch(&[a, b], &cfg).is_err());
    }

    #[test]
    fn batch_with_single_signal_matches_driver_shape() {
        let (xs, _) = corpus(1, 7);
        let cfg = BatchCdlConfig {
            n_atoms: 2,
            atom_dims: vec![8],
            max_iter: 3,
            seed: 7,
            ..Default::default()
        };
        let r = learn_dictionary_batch(&xs, &cfg).unwrap();
        assert_eq!(r.d.dims(), &[2, 1, 8]);
        assert!(r.trace.last().unwrap().cost.is_finite());
    }
}
