//! Batch CDL: learn one dictionary over a *collection* of observations.
//!
//! The paper's formulation is per-signal, but its sufficient-statistics
//! dictionary update (§4.2) extends directly to corpora: the objective
//! `sum_n 1/2 ||X_n - Z_n * D||^2 + lambda ||Z_n||_1` has
//! `phi = sum_n phi_n` and `psi = sum_n psi_n` as sufficient statistics,
//! so the dictionary step stays independent of both the signal sizes
//! and the corpus size.
//!
//! Two corpus drivers, selected by the backend:
//!
//! - **Per-signal resident pools** (persistent distributed backend):
//!   every signal gets its own [`WorkerPool`] kept alive across the
//!   whole alternation. Each outer iteration drives the per-pool
//!   `Solve` supervision loops **interleaved** — one supervisor thread
//!   per pool, so corpus signals overlap instead of queuing — and each
//!   pool's φ/ψ partials are computed the moment its own solve
//!   finishes (no cross-pool barrier between the two phases). The
//!   partials are then reduced in signal order (deterministic
//!   summation regardless of completion order) into one dictionary
//!   update, and `SetDict` re-broadcasts the accepted dictionary to
//!   every pool. No signal's Z is centralized until the final
//!   per-signal gather — this closes the "batch CDL on resident
//!   pools" and "interleave the per-pool Solve supervision loops"
//!   follow-ups from the persistent runtime work.
//!   (`IterRecord.csc_time` covers the whole interleaved solve+stats
//!   phase; `dict_time` is the reduce + PGD step.) Under
//!   `Alternation::Pipelined` every grid additionally keeps solving
//!   speculatively under the old dictionary while the reduce + PGD
//!   run, and the accepted dictionary lands as a mid-solve `SetDict`
//!   (see `dicod::pool` for the leg protocol).
//! - **Teardown** (sequential, or distributed with `persistent:
//!   false`): one warm-started one-shot solve per signal per
//!   iteration, statistics recomputed from the gathered activations.

use std::sync::Arc;
use std::time::Instant;

use crate::cdl::driver::{log_iter, CdlConfig, CscBackend, IterRecord};
use crate::cdl::init::init_dictionary;
use crate::csc::cd::{solve_cd_warm, CdConfig};
use crate::csc::problem::CscProblem;
use crate::csc::select::Strategy;
use crate::dicod::config::Alternation;
use crate::dicod::coordinator::solve_distributed_warm;
use crate::dicod::pool::{PoolReport, WorkerPool};
use crate::dict::grad::cost_from_stats;
use crate::dict::pgd::update_dict;
use crate::dict::phi_psi::{compute_stats_with_engine, DictStats};
use crate::tensor::NdTensor;

/// Batch CDL configuration.
///
/// This used to be a field-for-field near-copy of [`CdlConfig`] (minus
/// `verbose`, and silently ignoring `persistent`). It is now an alias
/// of the one shared core the `api` builder lowers to, so batch and
/// single-signal CDL cannot drift: batch honors `verbose`, and a
/// persistent distributed backend runs the per-signal resident-pool
/// driver.
///
/// Unifying the core also unified the defaults: `Default::default()`
/// now gives `max_iter = 30` (the `CdlConfig` default; the old
/// standalone batch struct said 20). Set `max_iter` explicitly if the
/// previous cap mattered.
pub type BatchCdlConfig = CdlConfig;

/// Batch CDL result.
#[derive(Clone, Debug)]
pub struct BatchCdlResult {
    pub d: NdTensor,
    /// Final activations per signal.
    pub zs: Vec<NdTensor>,
    pub lambda: f64,
    /// Total-objective trace (summed over the corpus).
    pub trace: Vec<IterRecord>,
    pub converged: bool,
    pub runtime: f64,
    /// Per-signal pool provenance when the resident-pool driver served
    /// the run (empty for the teardown modes).
    pub pools: Vec<PoolReport>,
}

/// Learn a dictionary over a corpus of observations (all with the same
/// channel count; spatial sizes may differ).
///
/// Thin wrapper over a one-shot [`crate::api::Session`]; use
/// `Session::fit_corpus` directly to keep the per-signal pools warm
/// after the call.
pub fn learn_dictionary_batch(
    xs: &[NdTensor],
    cfg: &BatchCdlConfig,
) -> anyhow::Result<BatchCdlResult> {
    crate::api::Session::from_cdl_config(cfg).fit_corpus_result(xs)
}

/// Validate the corpus and produce the initial dictionary, the fixed
/// regularization `lambda = lambda_frac * max_n lambda_max(X_n, D_0)`,
/// and the bootstrap engine (shared onward so the pools do not
/// recompute the spectra the lambda_max pass already built).
pub(crate) fn prepare_corpus(
    xs: &[NdTensor],
    cfg: &CdlConfig,
) -> anyhow::Result<(NdTensor, f64, crate::conv::CorrEngine)> {
    anyhow::ensure!(!xs.is_empty(), "empty corpus");
    let p = xs[0].dims()[0];
    for (i, x) in xs.iter().enumerate() {
        anyhow::ensure!(
            x.dims()[0] == p,
            "signal {i} has {} channels, expected {p}",
            x.dims()[0]
        );
        anyhow::ensure!(
            x.dims().len() == cfg.atom_dims.len() + 1,
            "signal {i} rank mismatch"
        );
    }
    // Initialize from the first signal's patches.
    let d = init_dictionary(&xs[0], cfg.n_atoms, &cfg.atom_dims, cfg.init, cfg.seed);
    // One engine for the whole corpus: the lambda_max bootstraps share
    // the dictionary spectra instead of rebuilding them per signal.
    let corr = crate::conv::CorrEngine::new(d.clone());
    let lambda = cfg.lambda_frac
        * xs.iter()
            .map(|x| corr.correlate_dict(x).norm_inf())
            .fold(0.0f64, f64::max);
    anyhow::ensure!(lambda > 0.0, "degenerate corpus: lambda_max = 0");
    Ok((d, lambda, corr))
}

/// Resident-pool corpus alternation: one already-running pool per
/// signal, all holding `(X_n, d0, lambda)`. Pools are left alive for
/// the caller (the session keeps them resident).
///
/// The per-signal `Solve` supervision loops run interleaved on scoped
/// threads — the paper's W-worker grid parallelism lives *inside* each
/// pool, and the supervision loops (cheap message pumps) overlap across
/// pools — with each pool's φ/ψ partials computed as soon as its solve
/// completes. Reduction happens in signal order after the join so the
/// summation, and hence the trace, is deterministic.
pub(crate) fn learn_batch_on_pools(
    pools: &mut [&mut WorkerPool],
    cfg: &CdlConfig,
    mut d: NdTensor,
    lambda: f64,
    start: Instant,
) -> anyhow::Result<BatchCdlResult> {
    // Every pool of a corpus run is spawned from the same backend
    // config, so the first pool's alternation mode speaks for all.
    if pools.first().map_or(false, |p| p.config().alternation == Alternation::Pipelined) {
        return learn_batch_on_pools_pipelined(pools, cfg, d, lambda, start);
    }
    let x_arcs: Vec<Arc<NdTensor>> = pools.iter().map(|p| p.problem().x_shared()).collect();
    let mut trace: Vec<IterRecord> = Vec::new();
    let mut converged = false;

    for it in 0..cfg.max_iter {
        // ---- interleaved per-signal phase: Solve then ComputeStats,
        // one supervisor thread per pool, no barrier between the two.
        // Panics (a wedged grid past its fail-loudly deadline) are
        // consumed at the manual join — the wedged pool is *abandoned*
        // (joining it would hang) and the iteration returns `Err`, so
        // one bad signal cannot poison the caller's other slot locks.
        let t0 = Instant::now();
        let joined: Vec<std::thread::Result<anyhow::Result<(DictStats, usize)>>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = pools
                    .iter_mut()
                    .enumerate()
                    .map(|(n, pool)| {
                        scope.spawn(move || {
                            let phase = pool.solve();
                            anyhow::ensure!(
                                !phase.diverged,
                                "distributed CSC diverged on corpus signal {n} at outer iteration {it}"
                            );
                            Ok(pool.compute_stats())
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join()).collect()
            });
        let csc_time = t0.elapsed().as_secs_f64();

        // ---- one dictionary update from partials reduced across pools,
        // in signal order. The objective is linear in (phi, psi,
        // ||X||^2, ||Z||_1), so summing per-signal statistics yields
        // the corpus objective.
        let t1 = Instant::now();
        let mut agg: Option<DictStats> = None;
        let mut nnz = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        for (n, r) in joined.into_iter().enumerate() {
            match r {
                Ok(Ok((s, z_nnz))) => {
                    nnz += z_nnz;
                    agg = Some(match agg {
                        None => s,
                        Some(mut a) => {
                            a.phi.add_assign(&s.phi);
                            a.psi.add_assign(&s.psi);
                            a.x_norm_sq += s.x_norm_sq;
                            a.z_l1 += s.z_l1;
                            a
                        }
                    });
                }
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    pools[n].abandon();
                    first_err.get_or_insert_with(|| {
                        anyhow::anyhow!(
                            "corpus supervisor for signal {n} panicked at outer iteration {it} \
                             (worker grid wedged); pool abandoned"
                        )
                    });
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let stats = agg.expect("corpus is non-empty");
        let cost_after_csc = cost_from_stats(&stats, &d, lambda);
        let pgd = update_dict(&stats, &d, lambda, &cfg.dict_cfg);
        d = pgd.d;
        let dict_time = t1.elapsed().as_secs_f64();

        let rec = IterRecord {
            iter: it,
            cost: pgd.cost,
            cost_after_csc,
            z_nnz: nnz,
            csc_time,
            dict_time,
            elapsed: start.elapsed().as_secs_f64(),
            phipsi_path: "worker-partials",
            // Barrier alternation: every grid idles for the whole
            // reduce + PGD span (supervisors still overlap across
            // pools, but no pool solves during the dictionary step).
            dict_wait_s: dict_time,
            overlap_updates: 0,
        };
        if cfg.verbose {
            log_iter(&rec);
        }
        let prev = trace.last().map(|r: &IterRecord| r.cost);
        trace.push(rec);
        if let Some(prev) = prev {
            let cur = trace.last().unwrap().cost;
            if (prev - cur).abs() / prev.abs().max(1e-300) < cfg.nu {
                converged = true;
            }
        }
        if converged || it + 1 == cfg.max_iter {
            break;
        }
        // ---- broadcast the accepted dictionary to every pool;
        //      workers re-bootstrap beta warm from their resident Z.
        //      One engine per broadcast round: its clones share the
        //      spectra cache, so the new dictionary's spectra are
        //      computed once, not once per signal. Broadcasts overlap
        //      across pools (each blocks on its own per-worker acks).
        let corr = crate::conv::CorrEngine::new(d.clone());
        let acks: Vec<std::thread::Result<()>> = std::thread::scope(|scope| {
            let handles: Vec<_> = pools
                .iter_mut()
                .zip(&x_arcs)
                .map(|(pool, x)| {
                    let problem = Arc::new(CscProblem::with_engine(
                        x.clone(),
                        d.clone(),
                        lambda,
                        corr.clone(),
                    ));
                    scope.spawn(move || pool.set_dict(problem))
                })
                .collect();
            handles.into_iter().map(|h| h.join()).collect()
        });
        for (n, a) in acks.iter().enumerate() {
            if a.is_err() {
                pools[n].abandon();
            }
        }
        anyhow::ensure!(
            acks.iter().all(|a| a.is_ok()),
            "corpus SetDict broadcast panicked at outer iteration {it} (wedged pool abandoned)"
        );
    }

    // The single per-signal centralization of the run (gathers overlap
    // across pools; results land in signal order).
    let gathered: Vec<std::thread::Result<NdTensor>> = std::thread::scope(|scope| {
        let handles: Vec<_> =
            pools.iter_mut().map(|pool| scope.spawn(move || pool.gather())).collect();
        handles.into_iter().map(|h| h.join()).collect()
    });
    let mut zs: Vec<NdTensor> = Vec::with_capacity(gathered.len());
    let mut gather_panic = false;
    for (n, g) in gathered.into_iter().enumerate() {
        match g {
            Ok(z) => zs.push(z),
            Err(_) => {
                pools[n].abandon();
                gather_panic = true;
            }
        }
    }
    anyhow::ensure!(!gather_panic, "corpus gather panicked (wedged pool abandoned)");
    let reports: Vec<PoolReport> = pools.iter().map(|p| p.report()).collect();

    Ok(BatchCdlResult {
        d,
        zs,
        lambda,
        trace,
        converged,
        runtime: start.elapsed().as_secs_f64(),
        pools: reports,
    })
}

/// Run `f` once per pool on scoped supervisor threads and join in
/// signal order. A panicking supervisor (a wedged grid past its
/// fail-loudly deadline) gets its pool abandoned — joining the grid
/// would hang — and the call returns `Err` after every thread has been
/// consumed, so one bad signal cannot poison the caller's other pools.
fn run_on_pools<T: Send>(
    pools: &mut [&mut WorkerPool],
    it: usize,
    what: &str,
    f: impl Fn(usize, &mut WorkerPool) -> T + Sync,
) -> anyhow::Result<Vec<T>> {
    let f = &f;
    let joined: Vec<std::thread::Result<T>> = std::thread::scope(|scope| {
        let handles: Vec<_> = pools
            .iter_mut()
            .enumerate()
            .map(|(n, pool)| scope.spawn(move || f(n, &mut **pool)))
            .collect();
        handles.into_iter().map(|h| h.join()).collect()
    });
    let mut out = Vec::with_capacity(joined.len());
    let mut first_err: Option<anyhow::Error> = None;
    for (n, r) in joined.into_iter().enumerate() {
        match r {
            Ok(v) => out.push(v),
            Err(_) => {
                pools[n].abandon();
                first_err.get_or_insert_with(|| {
                    anyhow::anyhow!(
                        "corpus {what} for signal {n} panicked at outer iteration {it} \
                         (worker grid wedged); pool abandoned"
                    )
                });
            }
        }
    }
    match first_err {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

/// Pipelined corpus alternation: after each pool ships its φ/ψ
/// partials, its grid resumes coordinate descent speculatively under
/// the old dictionary while this thread reduces the partials across
/// pools (still in signal order) and runs the PGD step. The accepted
/// dictionary then lands as a mid-solve `SetDict` in every pool, so
/// the already-running phases become the next iteration's CSC instead
/// of fresh `Solve` broadcasts — the grids never idle for the
/// dictionary step. Convergence gates are the same tolerance-based
/// ones as the single-signal pipelined driver; the barrier driver
/// above keeps bitwise reproducibility.
fn learn_batch_on_pools_pipelined(
    pools: &mut [&mut WorkerPool],
    cfg: &CdlConfig,
    mut d: NdTensor,
    lambda: f64,
    start: Instant,
) -> anyhow::Result<BatchCdlResult> {
    let x_arcs: Vec<Arc<NdTensor>> = pools.iter().map(|p| p.problem().x_shared()).collect();
    let mut trace: Vec<IterRecord> = Vec::new();
    let mut converged = false;
    let mut prev_overlap: u64 =
        pools.iter().map(|p| p.aggregate_stats().overlap_updates).sum();

    // Iteration 0's CSC phases; later iterations inherit the resumed
    // phases supervised by the previous leg's mid-solve `SetDict`.
    let t0 = Instant::now();
    let mut phases = run_on_pools(pools, 0, "Solve supervisor", |_, pool| pool.solve())?;
    let mut csc_time = t0.elapsed().as_secs_f64();

    for it in 0..cfg.max_iter {
        for (n, ph) in phases.iter().enumerate() {
            anyhow::ensure!(
                !ph.diverged,
                "distributed CSC diverged on corpus signal {n} at outer iteration {it} \
                 (divergence guard tripped; resident Z is unusable)"
            );
        }
        // Partials + speculative resume, interleaved across pools. The
        // grids only idle for the back-to-back broadcast pair; the
        // reduce + PGD below overlaps with the resumed solves.
        let legs = run_on_pools(pools, it, "ComputeStats supervisor", |_, pool| {
            pool.compute_stats_overlapped()
        })?;
        let dict_wait_s = legs.iter().map(|l| l.2).fold(0.0, f64::max);

        let t1 = Instant::now();
        let mut agg: Option<DictStats> = None;
        let mut nnz = 0usize;
        for (s, z_nnz, _) in legs {
            nnz += z_nnz;
            agg = Some(match agg {
                None => s,
                Some(mut a) => {
                    a.phi.add_assign(&s.phi);
                    a.psi.add_assign(&s.psi);
                    a.x_norm_sq += s.x_norm_sq;
                    a.z_l1 += s.z_l1;
                    a
                }
            });
        }
        let stats = agg.expect("corpus is non-empty");
        let cost_after_csc = cost_from_stats(&stats, &d, lambda);
        let pgd = update_dict(&stats, &d, lambda, &cfg.dict_cfg);
        d = pgd.d;
        let dict_time = t1.elapsed().as_secs_f64();
        let prev = trace.last().map(|r: &IterRecord| r.cost);
        let conv =
            prev.is_some_and(|prev| (prev - pgd.cost).abs() / prev.abs().max(1e-300) < cfg.nu);
        let last = it + 1 == cfg.max_iter;

        // Land the accepted dictionary mid-solve in every pool (one
        // shared engine per round, as in the barrier driver), or retire
        // the speculative phases when the alternation is over.
        let next_phases = if conv || last {
            run_on_pools(pools, it, "Stop supervisor", |_, pool| pool.stop_resumed_solve())?
        } else {
            let corr = crate::conv::CorrEngine::new(d.clone());
            let problems: Vec<Arc<CscProblem>> = x_arcs
                .iter()
                .map(|x| {
                    Arc::new(CscProblem::with_engine(x.clone(), d.clone(), lambda, corr.clone()))
                })
                .collect();
            let problems = &problems;
            run_on_pools(pools, it, "SetDict supervisor", move |n, pool| {
                pool.set_dict_midsolve(problems[n].clone())
            })?
        };

        let agg_overlap: u64 =
            pools.iter().map(|p| p.aggregate_stats().overlap_updates).sum();
        let rec = IterRecord {
            iter: it,
            cost: pgd.cost,
            cost_after_csc,
            z_nnz: nnz,
            csc_time,
            dict_time,
            elapsed: start.elapsed().as_secs_f64(),
            phipsi_path: "worker-partials",
            dict_wait_s,
            overlap_updates: agg_overlap - prev_overlap,
        };
        prev_overlap = agg_overlap;
        if cfg.verbose {
            log_iter(&rec);
        }
        trace.push(rec);
        if conv {
            converged = true;
        }
        if converged || last {
            break;
        }
        csc_time = next_phases.iter().map(|p| p.runtime).fold(0.0, f64::max);
        phases = next_phases;
    }

    // Same single per-signal centralization as the barrier driver.
    let zs = run_on_pools(pools, cfg.max_iter, "gather", |_, pool| pool.gather())?;
    let reports: Vec<PoolReport> = pools.iter().map(|p| p.report()).collect();

    Ok(BatchCdlResult {
        d,
        zs,
        lambda,
        trace,
        converged,
        runtime: start.elapsed().as_secs_f64(),
        pools: reports,
    })
}

/// Teardown corpus alternation: per-signal one-shot solves, each
/// warm-started from that signal's previous activations.
pub(crate) fn learn_batch_teardown(
    xs: &[NdTensor],
    cfg: &CdlConfig,
    mut d: NdTensor,
    lambda: f64,
    start: Instant,
) -> anyhow::Result<BatchCdlResult> {
    // Share each observation once; per-iteration problems reuse the
    // Arcs instead of recloning the corpus.
    let xs_shared: Vec<Arc<NdTensor>> = xs.iter().map(|x| Arc::new(x.clone())).collect();
    let mut zs: Vec<Option<NdTensor>> = vec![None; xs.len()];
    let mut trace: Vec<IterRecord> = Vec::new();
    let mut converged = false;

    for it in 0..cfg.max_iter {
        // ---- CSC per signal -------------------------------------------------
        let t0 = Instant::now();
        let mut cost_after_csc = 0.0;
        let mut nnz = 0usize;
        for (x, z_slot) in xs_shared.iter().zip(zs.iter_mut()) {
            let problem = CscProblem::new(x.clone(), d.clone(), lambda);
            let z = match &cfg.csc {
                CscBackend::Sequential => {
                    solve_cd_warm(
                        &problem,
                        &CdConfig {
                            strategy: Strategy::LocallyGreedy,
                            tol: cfg.csc_tol,
                            seed: cfg.seed,
                            ..Default::default()
                        },
                        z_slot.as_ref(),
                    )
                    .z
                }
                // The facade routes persistent backends to
                // `learn_batch_on_pools`; this arm keeps the match
                // total for the remaining (ephemeral) distributed case.
                CscBackend::Distributed(dcfg) | CscBackend::Persistent(dcfg) => {
                    let mut dcfg = dcfg.clone();
                    dcfg.tol = cfg.csc_tol;
                    solve_distributed_warm(&problem, &dcfg, z_slot.as_ref()).z
                }
            };
            cost_after_csc += problem.cost(&z);
            nnz += z.nnz();
            *z_slot = Some(z);
        }
        let csc_time = t0.elapsed().as_secs_f64();

        // ---- summed statistics + one dictionary update ----------------------
        let t1 = Instant::now();
        // One engine per outer iteration: the engine-aware dispatch adds
        // the FFT cross-spectra path for dense activations (early
        // iterations, before the codes sparsify).
        let stats_engine = crate::conv::CorrEngine::new(d.clone());
        let mut agg: Option<DictStats> = None;
        let mut phipsi_path: Option<&'static str> = None;
        for (x, z) in xs.iter().zip(&zs) {
            let (s, path) = compute_stats_with_engine(
                z.as_ref().unwrap(),
                x,
                &cfg.atom_dims,
                cfg.stat_workers,
                &stats_engine,
            );
            phipsi_path = Some(match phipsi_path {
                None => path,
                Some(prev) if prev == path => path,
                Some(_) => "mixed",
            });
            agg = Some(match agg {
                None => s,
                Some(mut a) => {
                    a.phi.add_assign(&s.phi);
                    a.psi.add_assign(&s.psi);
                    a.x_norm_sq += s.x_norm_sq;
                    a.z_l1 += s.z_l1;
                    a
                }
            });
        }
        let stats = agg.unwrap();
        let pgd = update_dict(&stats, &d, lambda, &cfg.dict_cfg);
        d = pgd.d;
        let dict_time = t1.elapsed().as_secs_f64();

        let rec = IterRecord {
            iter: it,
            cost: pgd.cost,
            cost_after_csc,
            z_nnz: nnz,
            csc_time,
            dict_time,
            elapsed: start.elapsed().as_secs_f64(),
            phipsi_path: phipsi_path.unwrap_or("sparse-seq"),
            dict_wait_s: 0.0,
            overlap_updates: 0,
        };
        if cfg.verbose {
            log_iter(&rec);
        }
        let prev = trace.last().map(|r| r.cost);
        trace.push(rec);
        if let Some(prev) = prev {
            let cur = trace.last().unwrap().cost;
            if (prev - cur).abs() / prev.abs().max(1e-300) < cfg.nu {
                converged = true;
                break;
            }
        }
    }

    Ok(BatchCdlResult {
        d,
        zs: zs.into_iter().map(|z| z.unwrap()).collect(),
        lambda,
        trace,
        converged,
        runtime: start.elapsed().as_secs_f64(),
        pools: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{best_atom_correlation, SyntheticConfig};
    use crate::dicod::config::DicodConfig;

    fn corpus(n: usize, seed: u64) -> (Vec<NdTensor>, NdTensor) {
        // Signals sharing one ground-truth dictionary.
        let mut gen = SyntheticConfig::signal_1d(500, 2, 8);
        gen.rho = 0.02;
        gen.noise_std = 0.02;
        let w0 = gen.generate(seed);
        let d_true = w0.d_true.clone();
        let mut xs = vec![w0.x];
        for i in 1..n {
            let mut rng = crate::util::rng::Pcg64::seeded(seed + 1000 + i as u64);
            let mut z = NdTensor::zeros(&[2, 493]);
            for v in z.data_mut().iter_mut() {
                if rng.bernoulli(0.02) {
                    *v = rng.normal_ms(0.0, 5.0);
                }
            }
            let clean = crate::conv::reconstruct(&z, &d_true);
            let noise = NdTensor::from_vec(clean.dims(), rng.normal_vec(clean.len())).scale(0.02);
            xs.push(clean.add(&noise));
        }
        (xs, d_true)
    }

    #[test]
    fn batch_cost_decreases() {
        let (xs, _) = corpus(3, 1);
        let cfg = BatchCdlConfig {
            n_atoms: 2,
            atom_dims: vec![8],
            max_iter: 6,
            seed: 1,
            ..Default::default()
        };
        let r = learn_dictionary_batch(&xs, &cfg).unwrap();
        assert!(r.trace.len() >= 2);
        for w in r.trace.windows(2) {
            assert!(w[1].cost <= w[0].cost * (1.0 + 1e-6) + 1e-9);
        }
        assert_eq!(r.zs.len(), 3);
        assert!(r.pools.is_empty(), "sequential corpus holds no pools");
    }

    #[test]
    fn batch_recovers_shared_dictionary() {
        let (xs, d_true) = corpus(4, 3);
        let cfg = BatchCdlConfig {
            n_atoms: 2,
            atom_dims: vec![8],
            max_iter: 20,
            lambda_frac: 0.03,
            csc_tol: 1e-5,
            seed: 3,
            ..Default::default()
        };
        let r = learn_dictionary_batch(&xs, &cfg).unwrap();
        let c0 = best_atom_correlation(r.d.slice0(0), &d_true, &[8]);
        let c1 = best_atom_correlation(r.d.slice0(1), &d_true, &[8]);
        assert!(c0.max(c1) > 0.9, "batch recovery failed: {c0:.3} {c1:.3}");
    }

    #[test]
    fn batch_rejects_bad_corpus() {
        assert!(learn_dictionary_batch(&[], &BatchCdlConfig::default()).is_err());
        let a = NdTensor::zeros(&[1, 50]);
        let b = NdTensor::zeros(&[2, 50]);
        let cfg = BatchCdlConfig { atom_dims: vec![8], ..Default::default() };
        assert!(learn_dictionary_batch(&[a, b], &cfg).is_err());
    }

    #[test]
    fn batch_with_single_signal_matches_driver_shape() {
        let (xs, _) = corpus(1, 7);
        let cfg = BatchCdlConfig {
            n_atoms: 2,
            atom_dims: vec![8],
            max_iter: 3,
            seed: 7,
            ..Default::default()
        };
        let r = learn_dictionary_batch(&xs, &cfg).unwrap();
        assert_eq!(r.d.dims(), &[2, 1, 8]);
        assert!(r.trace.last().unwrap().cost.is_finite());
    }

    #[test]
    fn batch_persistent_matches_teardown_trace() {
        let (xs, _) = corpus(2, 11);
        let mk = |persistent| BatchCdlConfig {
            n_atoms: 2,
            atom_dims: vec![8],
            max_iter: 4,
            nu: 0.0,
            csc_tol: 1e-6,
            lambda_frac: 0.05,
            csc: CscBackend::Distributed(DicodConfig {
                persistent,
                tol: 1e-6,
                ..DicodConfig::dicodile(2)
            }),
            seed: 11,
            ..Default::default()
        };
        let a = learn_dictionary_batch(&xs, &mk(true)).unwrap();
        let b = learn_dictionary_batch(&xs, &mk(false)).unwrap();
        assert_eq!(a.trace.len(), b.trace.len());
        for (ra, rb) in a.trace.iter().zip(&b.trace) {
            assert!(
                (ra.cost - rb.cost).abs() < 1e-4 * (1.0 + rb.cost.abs()),
                "iter {}: persistent {} vs teardown {}",
                ra.iter,
                ra.cost,
                rb.cost
            );
        }
        // Per-signal pool provenance: one resident pool per signal,
        // workers spawned exactly once, Z gathered exactly once.
        assert_eq!(a.pools.len(), xs.len());
        for report in &a.pools {
            assert_eq!(report.workers_spawned, report.n_workers);
            assert_eq!(report.stats.gathers, report.n_workers as u64);
        }
        assert!(b.pools.is_empty());
    }
}
