//! Dictionary initialization strategies.

use crate::tensor::NdTensor;
use crate::util::rng::Pcg64;

/// How to initialize the dictionary before alternating minimization.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitStrategy {
    /// iid Gaussian atoms, unit-normalized.
    Gaussian,
    /// Random patches extracted from the observation (the paper's image
    /// experiments initialize from data patches), unit-normalized.
    RandomPatches,
}

impl std::str::FromStr for InitStrategy {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s {
            "gaussian" => Ok(InitStrategy::Gaussian),
            "patches" | "random-patches" => Ok(InitStrategy::RandomPatches),
            other => Err(format!("unknown init {other:?} (gaussian|patches)")),
        }
    }
}

/// Build an initial dictionary `[K, P, L..]` for observation `x`.
pub fn init_dictionary(
    x: &NdTensor,
    n_atoms: usize,
    atom_dims: &[usize],
    strategy: InitStrategy,
    seed: u64,
) -> NdTensor {
    let mut rng = Pcg64::seeded(seed);
    let p = x.dims()[0];
    let tdims = &x.dims()[1..];
    let atom_sp: usize = atom_dims.iter().product();
    let mut ddims = vec![n_atoms, p];
    ddims.extend_from_slice(atom_dims);
    let mut vals = vec![0.0; n_atoms * p * atom_sp];

    match strategy {
        InitStrategy::Gaussian => {
            for v in vals.iter_mut() {
                *v = rng.normal();
            }
        }
        InitStrategy::RandomPatches => {
            for k in 0..n_atoms {
                // Random top-left corner such that the patch fits.
                let corner: Vec<usize> = tdims
                    .iter()
                    .zip(atom_dims)
                    .map(|(&t, &l)| {
                        assert!(t >= l, "atom larger than signal");
                        rng.below(t - l + 1)
                    })
                    .collect();
                for pi in 0..p {
                    let xs = x.slice0(pi);
                    let dst = &mut vals[(k * p + pi) * atom_sp..][..atom_sp];
                    copy_patch(xs, tdims, &corner, atom_dims, dst);
                }
            }
        }
    }

    // Normalize atoms to unit l2 norm (feasible + scale-fixed).
    for atom in vals.chunks_mut(p * atom_sp) {
        let n = atom.iter().map(|v| v * v).sum::<f64>().sqrt();
        if n > 1e-12 {
            for v in atom.iter_mut() {
                *v /= n;
            }
        } else {
            // Degenerate (flat) patch: fall back to noise.
            for v in atom.iter_mut() {
                *v = rng.normal();
            }
            let n2 = atom.iter().map(|v| v * v).sum::<f64>().sqrt();
            for v in atom.iter_mut() {
                *v /= n2;
            }
        }
    }

    NdTensor::from_vec(&ddims, vals)
}

fn copy_patch(src: &[f64], sdims: &[usize], corner: &[usize], pdims: &[usize], dst: &mut [f64]) {
    match sdims.len() {
        1 => {
            dst.copy_from_slice(&src[corner[0]..corner[0] + pdims[0]]);
        }
        2 => {
            let sw = sdims[1];
            let pw = pdims[1];
            for i in 0..pdims[0] {
                let srow = (corner[0] + i) * sw + corner[1];
                dst[i * pw..(i + 1) * pw].copy_from_slice(&src[srow..srow + pw]);
            }
        }
        _ => {
            let sstr = crate::tensor::shape::strides_of(sdims);
            let pstr = crate::tensor::shape::strides_of(pdims);
            for off in 0..dst.len() {
                let idx = crate::tensor::shape::index_of(off, pdims);
                let soff: usize = idx
                    .iter()
                    .zip(corner)
                    .zip(&sstr)
                    .map(|((i, c), s)| (i + c) * s)
                    .sum();
                let _ = &pstr;
                dst[off] = src[soff];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_init_normalized() {
        let x = NdTensor::zeros(&[2, 50]);
        let d = init_dictionary(&x, 4, &[8], InitStrategy::Gaussian, 1);
        assert_eq!(d.dims(), &[4, 2, 8]);
        for k in 0..4 {
            let n: f64 = d.slice0(k).iter().map(|v| v * v).sum();
            assert!((n - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn patch_init_extracts_from_signal() {
        let mut rng = Pcg64::seeded(3);
        let x = NdTensor::from_vec(&[1, 10, 10], rng.normal_vec(100));
        let d = init_dictionary(&x, 3, &[4, 4], InitStrategy::RandomPatches, 2);
        assert_eq!(d.dims(), &[3, 1, 4, 4]);
        // Each atom is a scaled patch of x: check one matches some patch.
        let atom = d.slice0(0);
        let mut found = false;
        'outer: for ci in 0..7 {
            for cj in 0..7 {
                // compare up to scale
                let mut patch = vec![0.0; 16];
                for i in 0..4 {
                    for j in 0..4 {
                        patch[i * 4 + j] = x.at(&[0, ci + i, cj + j]);
                    }
                }
                let pn = patch.iter().map(|v| v * v).sum::<f64>().sqrt();
                let diff: f64 = patch
                    .iter()
                    .zip(atom)
                    .map(|(p, a)| (p / pn - a).abs())
                    .sum();
                if diff < 1e-9 {
                    found = true;
                    break 'outer;
                }
            }
        }
        assert!(found, "atom is not a normalized patch of x");
    }

    #[test]
    fn flat_signal_falls_back_to_noise() {
        let x = NdTensor::zeros(&[1, 30]);
        let d = init_dictionary(&x, 2, &[5], InitStrategy::RandomPatches, 4);
        for k in 0..2 {
            let n: f64 = d.slice0(k).iter().map(|v| v * v).sum();
            assert!((n - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let x = NdTensor::zeros(&[1, 30]);
        let a = init_dictionary(&x, 2, &[5], InitStrategy::Gaussian, 9);
        let b = init_dictionary(&x, 2, &[5], InitStrategy::Gaussian, 9);
        assert!(a.allclose(&b, 0.0));
    }
}
