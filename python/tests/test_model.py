"""L2 model correctness: conv-based graph vs the loop-based oracles,
plus shape checks for every artifact function in 1-D and 2-D."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def rng(seed):
    return np.random.default_rng(seed)


def make_workload(seed, rank, k=3, p=2, length=5, v=17):
    r = rng(seed)
    if rank == 1:
        ld, vd = (length,), (v,)
    else:
        ld, vd = (length, length), (v, v)
    td = tuple(a + b - 1 for a, b in zip(vd, ld))
    x = jnp.asarray(r.normal(size=(p,) + td))
    d = jnp.asarray(r.normal(size=(k, p) + ld))
    z = jnp.asarray(r.normal(size=(k,) + vd) * (r.uniform(size=(k,) + vd) < 0.2))
    return x, d, z, ld


@settings(max_examples=10, deadline=None)
@given(rank=st.integers(1, 2), seed=st.integers(0, 2**31 - 1))
def test_reconstruct_matches_ref(rank, seed):
    x, d, z, _ = make_workload(seed, rank)
    got = model.reconstruct(z, d)
    want = ref.reconstruct_ref(z, d)
    assert got.shape == x.shape
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


@settings(max_examples=8, deadline=None)
@given(rank=st.integers(1, 2), seed=st.integers(0, 2**31 - 1))
def test_cost_eval_matches_ref(rank, seed):
    x, d, z, _ = make_workload(seed, rank)
    (got,) = model.cost_eval(x, d, z)
    want = ref.data_fit_ref(x, d, z)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(rank=st.integers(1, 2), seed=st.integers(0, 2**31 - 1))
def test_phi_psi_match_ref(rank, seed):
    x, d, z, ld = make_workload(seed, rank)
    phi, psi = model.phi_psi(z, x, ld)
    phi_want = ref.phi_ref(z, ld)
    psi_want = ref.psi_ref(z, x, ld)
    assert phi.shape == phi_want.shape
    assert psi.shape == psi_want.shape
    np.testing.assert_allclose(phi, phi_want, rtol=1e-6, atol=1e-8)
    np.testing.assert_allclose(psi, psi_want, rtol=1e-6, atol=1e-8)


@settings(max_examples=8, deadline=None)
@given(rank=st.integers(1, 2), seed=st.integers(0, 2**31 - 1))
def test_dict_grad_matches_ref(rank, seed):
    x, d, z, ld = make_workload(seed, rank)
    phi = ref.phi_ref(z, ld)
    psi = ref.psi_ref(z, x, ld)
    (got,) = model.dict_grad(phi, psi, d)
    want = ref.dict_grad_ref(phi, psi, d)
    assert got.shape == d.shape
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


def test_dict_grad_is_true_gradient():
    # Autodiff cross-check: grad of 1/2||X - Z*D||^2 wrt D equals the
    # stats-based gradient.
    x, d, z, ld = make_workload(7, 1)
    phi = ref.phi_ref(z, ld)
    psi = ref.psi_ref(z, x, ld)
    (got,) = model.dict_grad(phi, psi, d)
    want = jax.grad(lambda dd: ref.data_fit_ref(x, dd, z))(d)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


def test_beta_init_equals_neg_gradient_at_zero():
    # beta at Z=0 is corr(X, D) = -grad of the smooth part at 0.
    x, d, z, _ = make_workload(9, 1)
    (beta,) = model.beta_init(x, d)
    want = -jax.grad(lambda zz: ref.data_fit_ref(x, d, zz))(jnp.zeros_like(z))
    np.testing.assert_allclose(beta, want, rtol=1e-6, atol=1e-8)


def test_lgcd_step_wrapper_shapes():
    x, d, z, _ = make_workload(11, 2)
    (beta,) = model.beta_init(x, d)
    norms = jnp.sum(d * d, axis=tuple(range(1, d.ndim)))
    (dz,) = model.lgcd_step(beta, z, norms, jnp.asarray(0.1))
    assert dz.shape == z.shape
    want = ref.lgcd_step_ref(beta, z, norms, 0.1)
    np.testing.assert_allclose(dz, want, rtol=1e-6, atol=1e-8)


def test_full_csc_objective_consistency():
    # cost_eval + lambda * l1 == cost_ref.
    x, d, z, _ = make_workload(13, 1)
    lam = 0.37
    (fit,) = model.cost_eval(x, d, z)
    total = fit + lam * jnp.sum(jnp.abs(z))
    np.testing.assert_allclose(total, ref.cost_ref(x, d, z, lam), rtol=1e-6)
