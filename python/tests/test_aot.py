"""AOT pipeline checks: manifest integrity and numerical equivalence of
the lowered HLO (executed through XLA from python) with the model."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_shapes_for_consistency():
    s = aot.shapes_for(aot.CONFIGS["tiny_1d"])
    assert s["x"] == (1, 64)
    assert s["d"] == (3, 1, 8)
    assert s["z"] == (3, 57)
    assert s["phi"] == (3, 3, 15)
    assert s["psi"] == (3, 1, 8)


def test_lower_single_config_manifest():
    with tempfile.TemporaryDirectory() as td:
        manifest = aot.lower_all(td, {"tiny_1d": aot.CONFIGS["tiny_1d"]})
        assert len(manifest["artifacts"]) == 5
        # files exist and are parseable HLO text
        for entry in manifest["artifacts"]:
            path = os.path.join(td, entry["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert "HloModule" in text
            assert len(text) > 100
        # manifest round-trips through json
        with open(os.path.join(td, "manifest.json")) as f:
            back = json.load(f)
        assert back["artifacts"] == manifest["artifacts"]


def test_hlo_text_parses_and_declares_right_shapes():
    """Round-trip the HLO text through the XLA parser (the operation the
    rust runtime performs) and check the entry computation signature.
    Full execute-parity is covered by rust/tests/artifact_parity.rs."""
    from jax._src.lib import xla_client as xc

    cfg = aot.CONFIGS["tiny_1d"]
    s = aot.shapes_for(cfg)
    fn = lambda x, d: model.beta_init(x, d)  # noqa: E731
    lowered = jax.jit(fn).lower(aot.spec(s["x"]), aot.spec(s["d"]))
    text = aot.to_hlo_text(lowered)

    mod = xc._xla.hlo_module_from_text(text)
    sig = mod.to_string(xc._xla.HloPrintOptions.short_parsable())
    # entry params carry the lowered input shapes; the root is a tuple
    # holding the [K, T'] beta.
    assert "f32[1,64]" in sig
    assert "f32[3,1,8]" in sig
    assert "f32[3,57]" in sig


def test_lowered_graphs_match_eager_numerics():
    """jit-compiled (XLA) vs eager execution of every op — guards the
    lowering path the artifacts take."""
    cfg = aot.CONFIGS["tiny_2d"]
    s = aot.shapes_for(cfg)
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=s["x"]), dtype=jnp.float32)
    d = jnp.asarray(r.normal(size=s["d"]), dtype=jnp.float32)
    z = jnp.asarray(r.normal(size=s["z"]), dtype=jnp.float32)
    for name, fn, args in [
        ("beta_init", lambda: model.beta_init(x, d), None),
        ("cost_eval", lambda: model.cost_eval(x, d, z), None),
        ("phi_psi", lambda: model.phi_psi(z, x, tuple(cfg["l"])), None),
    ]:
        del args
        eager = fn()
        jitted = jax.jit(fn)()
        for a, b in zip(eager, jitted):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6, err_msg=name)
