"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is
the core correctness signal of the AOT layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import corr, lgcd_step, ref

jax.config.update("jax_enable_x64", True)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# lgcd_step kernel
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 6),
    n=st.integers(1, 300),
    lam=st.floats(0.01, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_lgcd_step_matches_ref_1d(k, n, lam, seed):
    r = rng(seed)
    beta = jnp.asarray(r.normal(size=(k, n)) * 3)
    z = jnp.asarray(r.normal(size=(k, n)))
    norms = jnp.asarray(r.uniform(0.5, 2.0, size=(k,)))
    got = lgcd_step.lgcd_step(beta, z, norms, jnp.asarray(lam))
    want = ref.lgcd_step_ref(beta, z, norms, lam)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


@settings(max_examples=15, deadline=None)
@given(
    k=st.integers(1, 4),
    h=st.integers(1, 24),
    w=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_lgcd_step_matches_ref_2d(k, h, w, seed):
    r = rng(seed)
    beta = jnp.asarray(r.normal(size=(k, h, w)) * 3)
    z = jnp.asarray(r.normal(size=(k, h, w)))
    norms = jnp.asarray(r.uniform(0.5, 2.0, size=(k,)))
    got = lgcd_step.lgcd_step(beta, z, norms, jnp.asarray(0.5))
    want = ref.lgcd_step_ref(beta, z, norms, 0.5)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_lgcd_step_dtypes(dtype):
    r = rng(0)
    beta = jnp.asarray(r.normal(size=(3, 50)), dtype=dtype)
    z = jnp.zeros((3, 50), dtype=dtype)
    norms = jnp.ones((3,), dtype=dtype)
    got = lgcd_step.lgcd_step(beta, z, norms, jnp.asarray(0.1, dtype=dtype))
    assert got.dtype == dtype
    want = ref.lgcd_step_ref(beta, z, norms, 0.1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_lgcd_step_zero_at_fixed_point():
    # beta = z * norms with |beta| <= lam + z*norms means ST pulls toward
    # the fixed point; specifically dz = 0 when ST(beta)/n == z.
    z = jnp.asarray([[0.5, -1.0, 0.0]])
    norms = jnp.asarray([2.0])
    lam = 0.3
    beta = z * norms + jnp.sign(z) * lam  # ST(beta, lam)/n == z on support
    got = lgcd_step.lgcd_step(beta, z, norms, jnp.asarray(lam))
    np.testing.assert_allclose(got[0, :2], 0.0, atol=1e-12)


def test_lgcd_step_block_boundary_sizes():
    # Sizes straddling the BLOCK padding logic.
    for n in [lgcd_step.BLOCK - 1, lgcd_step.BLOCK, lgcd_step.BLOCK + 1]:
        r = rng(n)
        beta = jnp.asarray(r.normal(size=(2, n)))
        z = jnp.asarray(r.normal(size=(2, n)))
        norms = jnp.asarray([1.0, 2.0])
        got = lgcd_step.lgcd_step(beta, z, norms, jnp.asarray(0.2))
        want = ref.lgcd_step_ref(beta, z, norms, 0.2)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


# ---------------------------------------------------------------------------
# corr kernel
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    k=st.integers(1, 4),
    p=st.integers(1, 3),
    length=st.integers(1, 12),
    extra=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_corr_1d_matches_ref(k, p, length, extra, seed):
    r = rng(seed)
    t = length + extra - 1  # T' = extra
    x = jnp.asarray(r.normal(size=(p, t)))
    d = jnp.asarray(r.normal(size=(k, p, length)))
    got = corr.correlate_dict(x, d)
    want = ref.correlate_dict_ref(x, d)
    assert got.shape == (k, extra)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


@settings(max_examples=10, deadline=None)
@given(
    k=st.integers(1, 3),
    p=st.integers(1, 2),
    l0=st.integers(1, 6),
    l1=st.integers(1, 6),
    v0=st.integers(1, 20),
    v1=st.integers(1, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_corr_2d_matches_ref(k, p, l0, l1, v0, v1, seed):
    r = rng(seed)
    x = jnp.asarray(r.normal(size=(p, v0 + l0 - 1, v1 + l1 - 1)))
    d = jnp.asarray(r.normal(size=(k, p, l0, l1)))
    got = corr.correlate_dict(x, d)
    want = ref.correlate_dict_ref(x, d)
    assert got.shape == (k, v0, v1)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


def test_corr_block_boundaries_1d():
    for v in [corr.BLOCK - 1, corr.BLOCK, corr.BLOCK + 1]:
        r = rng(v)
        x = jnp.asarray(r.normal(size=(1, v + 7)))
        d = jnp.asarray(r.normal(size=(2, 1, 8)))
        got = corr.correlate_dict(x, d)
        want = ref.correlate_dict_ref(x, d)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-8)


def test_corr_delta_atom_slides():
    # A one-hot atom extracts the corresponding window of X.
    x = jnp.arange(20.0)[None, :]
    d = jnp.zeros((1, 1, 4)).at[0, 0, 2].set(1.0)
    got = corr.correlate_dict(x, d)
    np.testing.assert_allclose(got[0], np.arange(2.0, 19.0))
