"""AOT pipeline: lower the L2 model (with its L1 Pallas kernels) to HLO
text artifacts + manifest.json for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the runtime's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts

Artifacts are lowered in f32 for a fixed set of workload configurations
(the shapes the examples/benches/parity-tests use). The rust runtime
matches artifacts by (op name, exact input shapes) and falls back to
its native implementations for any other shape.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Workload configurations to lower. Keep in sync with:
#   examples/quickstart.rs           (quickstart_1d)
#   examples/hubble_patterns.rs      (hubble_2d)
#   rust/tests/artifact_parity.rs    (tiny_1d, tiny_2d)
CONFIGS = {
    "tiny_1d": dict(p=1, k=3, l=(8,), t=(64,)),
    "tiny_2d": dict(p=1, k=2, l=(4, 4), t=(16, 16)),
    "quickstart_1d": dict(p=1, k=5, l=(32,), t=(2000,)),
    "hubble_2d": dict(p=1, k=9, l=(12, 12), t=(200, 300)),
}

DTYPE = jnp.float32


def spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), DTYPE)


def shapes_for(cfg):
    """All tensor shapes of a workload configuration."""
    p, k = cfg["p"], cfg["k"]
    l, t = tuple(cfg["l"]), tuple(cfg["t"])
    v = tuple(ti - li + 1 for ti, li in zip(t, l))
    cc = tuple(2 * li - 1 for li in l)
    return {
        "x": (p,) + t,
        "d": (k, p) + l,
        "z": (k,) + v,
        "phi": (k, k) + cc,
        "psi": (k, p) + l,
        "norms": (k,),
        "lam": (1,),
    }


def ops_for(cfg):
    """(op name, callable, input shapes) triples for one configuration."""
    s = shapes_for(cfg)
    ldims = tuple(cfg["l"])
    return [
        ("beta_init", lambda x, d: model.beta_init(x, d), [s["x"], s["d"]]),
        ("cost_eval", lambda x, d, z: model.cost_eval(x, d, z), [s["x"], s["d"], s["z"]]),
        (
            "dict_grad",
            lambda phi, psi, d: model.dict_grad(phi, psi, d),
            [s["phi"], s["psi"], s["d"]],
        ),
        ("phi_psi", lambda z, x: model.phi_psi(z, x, ldims), [s["z"], s["x"]]),
        (
            "lgcd_step",
            lambda beta, z, norms, lam: model.lgcd_step(beta, z, norms, lam),
            [s["z"], s["z"], s["norms"], s["lam"]],
        ),
    ]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str, configs=None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "dtype": "f32", "artifacts": []}
    for cfg_name, cfg in (configs or CONFIGS).items():
        for op_name, fn, in_shapes in ops_for(cfg):
            args = [spec(sh) for sh in in_shapes]
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            out_shapes = [
                list(o.shape) for o in jax.eval_shape(fn, *args)
            ]
            fname = f"{op_name}__{cfg_name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["artifacts"].append(
                {
                    "name": op_name,
                    "config": cfg_name,
                    "file": fname,
                    "inputs": [list(sh) for sh in in_shapes],
                    "outputs": out_shapes,
                }
            )
            print(f"  {op_name:10} {cfg_name:14} {len(text):>9} chars")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--configs",
        default="",
        help="comma-separated subset of configs (default: all)",
    )
    args = ap.parse_args()
    configs = CONFIGS
    if args.configs:
        names = [c for c in args.configs.split(",") if c]
        configs = {n: CONFIGS[n] for n in names}
    manifest = lower_all(args.out, configs)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
