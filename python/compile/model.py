"""Layer-2 JAX compute graph: the batch-heavy algebra of DiCoDiLe.

Five jit-able functions, each lowered to one HLO artifact by aot.py
(shapes are baked at lowering time; see artifacts/manifest.json):

  beta_init(x, d)         -> (beta,)        corr(X, D), via the Pallas kernel
  cost_eval(x, d, z)      -> (data_fit,)    1/2 ||X - Z*D||^2
  dict_grad(phi, psi, d)  -> (grad,)        eq. 16 gradient from the stats
  phi_psi(z, x)           -> (phi, psi)     eq. 17 sufficient statistics
  lgcd_step(beta,z,n,lam) -> (dz,)          eq. 7 candidate map (Pallas)

All functions support 1-D and 2-D spatial domains and mirror the rust
conventions (channels-first; Z on the valid domain). The convolutional
pieces use lax.conv_general_dilated so XLA emits fused convolutions;
each is validated against the loop-based oracles in kernels/ref.py.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .kernels import corr as corr_kernel
from .kernels import lgcd_step as lgcd_kernel


def _dn(rank):
    """Conv dimension numbers for rank spatial dims, channels-first."""
    if rank == 1:
        return ("NCH", "OIH", "NCH")
    if rank == 2:
        return ("NCHW", "OIHW", "NCHW")
    raise ValueError(f"unsupported spatial rank {rank}")


def _flip_spatial(a, n_lead):
    axes = tuple(range(n_lead, a.ndim))
    return jnp.flip(a, axis=axes)


def beta_init(x, d):
    """(corr(X, D),) — the CSC warm start; body is the Pallas kernel."""
    return (corr_kernel.correlate_dict(x, d),)


def reconstruct(z, d):
    """Z * D : [P, T..] (full convolution, valid-domain activations)."""
    rank = z.ndim - 1
    ldims = d.shape[2:]
    # in: [N=1, C=K, T'..]; ker: [O=P, I=K, L..] spatially flipped;
    # padding L-1 turns correlation into full convolution.
    inp = z[None]
    ker = _flip_spatial(jnp.swapaxes(d, 0, 1), 2)
    pad = [(l - 1, l - 1) for l in ldims]
    out = lax.conv_general_dilated(
        inp, ker, window_strides=(1,) * rank, padding=pad,
        dimension_numbers=_dn(rank),
    )
    return out[0]


def cost_eval(x, d, z):
    """(1/2 ||X - Z*D||^2,) — the lambda ||Z||_1 term is added by the
    caller in f64 (see rust runtime::hybrid)."""
    resid = x - reconstruct(z, d)
    return (0.5 * jnp.sum(resid * resid),)


def dict_grad(phi, psi, d):
    """(grad_D F,) from the sufficient statistics (eq. 16)."""
    rank = d.ndim - 2
    k = d.shape[0]
    ldims = d.shape[2:]
    # in: [N=P, C=K', L..]; ker: [O=K, I=K', (2L-1)..] = flip(phi);
    # padding L-1 gives output spatial extent L.
    inp = jnp.swapaxes(d, 0, 1)
    ker = _flip_spatial(phi, 2)
    pad = [(l - 1, l - 1) for l in ldims]
    out = lax.conv_general_dilated(
        inp, ker, window_strides=(1,) * rank, padding=pad,
        dimension_numbers=_dn(rank),
    )
    grad = jnp.swapaxes(out, 0, 1)
    del k
    return (grad - psi,)


def phi_psi(z, x, ldims):
    """((phi, psi)) — eq. 17 statistics.

    phi via z (*) z correlation with padding L-1 (output (2L-1)..);
    psi via x (*) z valid correlation (output L..).
    """
    rank = z.ndim - 1
    k = z.shape[0]
    # phi: in [N=K', C=1, T'..], ker [O=K, I=1, T'..], pad L-1.
    inp = z[:, None]
    ker = z[:, None]
    pad = [(l - 1, l - 1) for l in ldims]
    phi = lax.conv_general_dilated(
        inp, ker, window_strides=(1,) * rank, padding=pad,
        dimension_numbers=_dn(rank),
    )
    # out[n=k', o=k, delta] -> [k, k', delta]
    phi = jnp.swapaxes(phi, 0, 1)
    # psi: in [N=P, C=1, T..], ker [O=K, I=1, T'..], valid padding.
    psi = lax.conv_general_dilated(
        x[:, None], ker, window_strides=(1,) * rank,
        padding=[(0, 0)] * rank, dimension_numbers=_dn(rank),
    )
    psi = jnp.swapaxes(psi, 0, 1)
    del k
    return (phi, psi)


def lgcd_step(beta, z, norms_sq, lam):
    """(dZ,) — eq. 7 candidate map; body is the Pallas kernel."""
    return (lgcd_kernel.lgcd_step(beta, z, norms_sq, lam),)
