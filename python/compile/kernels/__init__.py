"""Layer-1 Pallas kernels and their pure-jnp reference oracles."""

from . import corr, lgcd_step, ref  # noqa: F401
