"""Layer-1 Pallas kernel: dictionary correlation (the beta bootstrap).

    out[k, u] = sum_{p, l} X[p, u + l] D[k, p, l]

This is the single most FLOP-heavy step of each CSC solve
(O(K P |Theta| |Omega|)), and the body of the `beta_init` artifact.

TPU mapping (DESIGN.md §Hardware-Adaptation): the output is tiled over
(atom, spatial block). Each grid step holds one output tile of BLOCK
positions, the full observation window it needs (BLOCK + L - 1 halo per
spatial dim, channels-major) and one atom in VMEM, and reduces over the
atom support with unrolled shifted windows — each shift is a
(P,BLOCK)x(P,) contraction, which batches into an MXU matmul of shape
(BLOCK, P*|Theta|) x (P*|Theta|, 1) after the unroll. For the artifact
shapes (P<=8, L<=32, BLOCK=1024) the VMEM footprint is
(BLOCK + L) * P * 4B + P * L * 4B < 300 KiB per step. interpret=True on
CPU; checked against ref.correlate_dict_ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output positions per grid step.
BLOCK = 1024


def _make_kernel_1d(p, length, block):
    def kernel(x_ref, d_ref, out_ref):
        ti = pl.program_id(1)
        xs = x_ref[...]  # [P, T_padded] (full observation in VMEM)
        dk = d_ref[...]  # [1, P, L]
        acc = jnp.zeros((block,), dtype=xs.dtype)
        zero = jnp.int32(0)
        u0 = (ti * block).astype(jnp.int32)
        for li in range(length):  # unrolled over the atom support
            win = jax.lax.dynamic_slice(xs, (zero, u0 + jnp.int32(li)), (p, block))
            acc = acc + jnp.einsum("pt,p->t", win, dk[0, :, li])
        out_ref[...] = acc[None, :]

    return kernel


def correlate_dict(x, d):
    """Pallas-backed corr(X, D) -> [K, T'..] (1-D or 2-D spatial)."""
    k, p = d.shape[0], d.shape[1]
    ldims = d.shape[2:]
    tdims = x.shape[1:]
    vdims = tuple(t - l + 1 for t, l in zip(tdims, ldims))
    if len(ldims) == 1:
        return _corr_1d(x, d, k, p, ldims[0], vdims[0])
    if len(ldims) == 2:
        # 2-D: flatten rows into the grid, block along the last axis.
        return _corr_2d(x, d, k, p, ldims, vdims)
    raise ValueError(f"unsupported spatial rank {len(ldims)}")


def _corr_1d(x, d, k, p, length, v):
    pad = (-v) % BLOCK
    vp = v + pad
    # x must cover indices up to vp - 1 + L - 1.
    xp = jnp.pad(x, ((0, 0), (0, vp + length - 1 - x.shape[1])))
    out = pl.pallas_call(
        _make_kernel_1d(p, length, BLOCK),
        grid=(k, vp // BLOCK),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda ki, ti: (0, 0)),
            pl.BlockSpec((1, p, length), lambda ki, ti: (ki, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda ki, ti: (ki, ti)),
        out_shape=jax.ShapeDtypeStruct((k, vp), x.dtype),
        interpret=True,
    )(xp, d)
    return out[:, :v]


# 2-D: one output row per grid step, blocked along the width.
ROW_BLOCK = 256


def _make_kernel_2d(p, l0, l1, block):
    def kernel(x_ref, d_ref, out_ref):
        ri = pl.program_id(1).astype(jnp.int32)
        ci = pl.program_id(2)
        xs = x_ref[...]  # [P, Hp, Wp]
        dk = d_ref[...]  # [1, P, L0, L1]
        acc = jnp.zeros((block,), dtype=xs.dtype)
        zero = jnp.int32(0)
        c0 = (ci * block).astype(jnp.int32)
        for li in range(l0):
            for lj in range(l1):
                win = jax.lax.dynamic_slice(
                    xs, (zero, ri + jnp.int32(li), c0 + jnp.int32(lj)), (p, 1, block)
                )
                acc = acc + jnp.einsum("pt,p->t", win[:, 0, :], dk[0, :, li, lj])
        out_ref[...] = acc[None, None, :]

    return kernel


def _corr_2d(x, d, k, p, ldims, vdims):
    l0, l1 = ldims
    v0, v1 = vdims
    pad1 = (-v1) % ROW_BLOCK
    v1p = v1 + pad1
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (0, v0 + l0 - 1 - x.shape[1]),
            (0, v1p + l1 - 1 - x.shape[2]),
        ),
    )
    out = pl.pallas_call(
        _make_kernel_2d(p, l0, l1, ROW_BLOCK),
        grid=(k, v0, v1p // ROW_BLOCK),
        in_specs=[
            pl.BlockSpec(xp.shape, lambda ki, ri, ci: (0, 0, 0)),
            pl.BlockSpec((1, p, l0, l1), lambda ki, ri, ci: (ki, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, ROW_BLOCK), lambda ki, ri, ci: (ki, ri, ci)),
        out_shape=jax.ShapeDtypeStruct((k, v0, v1p), x.dtype),
        interpret=True,
    )(xp, d)
    return out[:, :, :v1]
