"""Pure-jnp oracles for the Pallas kernels and the L2 model.

Every kernel in this package is checked against these reference
implementations (pytest + hypothesis sweeps). They mirror the rust
conventions exactly (see rust/src/conv/mod.rs):

  X : [P, T..]       observation (channels-first)
  D : [K, P, L..]    dictionary
  Z : [K, T'..]      activations on the valid domain T' = T - L + 1
"""

from __future__ import annotations

import jax.numpy as jnp


def soft_threshold(u, lam):
    """ST(u, lam) = sign(u) max(|u| - lam, 0)."""
    return jnp.sign(u) * jnp.maximum(jnp.abs(u) - lam, 0.0)


def lgcd_step_ref(beta, z, norms_sq, lam):
    """Optimal additive updates dZ = ST(beta, lam)/||D_k||^2 - Z.

    beta, z : [K, T'..] ; norms_sq : [K] ; lam : scalar.
    The per-coordinate LGCD candidate map (eq. 7 of the paper).
    """
    expand = (...,) + (None,) * (beta.ndim - 1)
    return soft_threshold(beta, lam) / norms_sq[expand] - z


def correlate_dict_ref(x, d):
    """beta bootstrap corr(X, D)[k, u] = sum_{p,l} X[p, u+l] D[k,p,l].

    Works for d = 1 or 2 spatial dims. Returns [K, T'..].
    """
    k, p = d.shape[0], d.shape[1]
    ldims = d.shape[2:]
    tdims = x.shape[1:]
    vdims = tuple(t - l + 1 for t, l in zip(tdims, ldims))
    out = jnp.zeros((k,) + vdims, dtype=x.dtype)
    if len(ldims) == 1:
        (L,) = ldims
        for li in range(L):
            # window X[:, li : li + T'] against D[:, :, li]
            win = x[:, li : li + vdims[0]]  # [P, T']
            out = out + jnp.einsum("pt,kp->kt", win, d[:, :, li])
    elif len(ldims) == 2:
        L0, L1 = ldims
        for li in range(L0):
            for lj in range(L1):
                win = x[:, li : li + vdims[0], lj : lj + vdims[1]]
                out = out + jnp.einsum("pij,kp->kij", win, d[:, :, li, lj])
    else:
        raise ValueError(f"unsupported spatial rank {len(ldims)}")
    return out


def reconstruct_ref(z, d):
    """Z * D : [P, T..] = sum_k full_conv(Z_k, D_k[p])."""
    k, p = d.shape[0], d.shape[1]
    ldims = d.shape[2:]
    vdims = z.shape[1:]
    tdims = tuple(v + l - 1 for v, l in zip(vdims, ldims))
    out = jnp.zeros((p,) + tdims, dtype=z.dtype)
    if len(ldims) == 1:
        (L,) = ldims
        for li in range(L):
            out = out.at[:, li : li + vdims[0]].add(
                jnp.einsum("kt,kp->pt", z, d[:, :, li])
            )
    elif len(ldims) == 2:
        L0, L1 = ldims
        for li in range(L0):
            for lj in range(L1):
                out = out.at[:, li : li + vdims[0], lj : lj + vdims[1]].add(
                    jnp.einsum("kij,kp->pij", z, d[:, :, li, lj])
                )
    else:
        raise ValueError(f"unsupported spatial rank {len(ldims)}")
    return out


def cost_ref(x, d, z, lam):
    """Full objective 1/2 ||X - Z*D||^2 + lam ||Z||_1."""
    resid = x - reconstruct_ref(z, d)
    return 0.5 * jnp.sum(resid * resid) + lam * jnp.sum(jnp.abs(z))


def data_fit_ref(x, d, z):
    """1/2 ||X - Z*D||^2 only (the artifact-side part of the cost)."""
    resid = x - reconstruct_ref(z, d)
    return 0.5 * jnp.sum(resid * resid)


def phi_ref(z, ldims):
    """phi[k,k'][delta + L - 1] = sum_u Z_k[u] Z_k'[u + delta]."""
    k = z.shape[0]
    vdims = z.shape[1:]
    cc = tuple(2 * l - 1 for l in ldims)
    out = jnp.zeros((k, k) + cc, dtype=z.dtype)
    if len(ldims) == 1:
        (L,) = ldims
        zp = jnp.pad(z, ((0, 0), (L - 1, L - 1)))
        for i, delta in enumerate(range(-(L - 1), L)):
            shifted = zp[:, L - 1 + delta : L - 1 + delta + vdims[0]]
            out = out.at[:, :, i].set(jnp.einsum("kt,jt->kj", z, shifted))
    elif len(ldims) == 2:
        L0, L1 = ldims
        zp = jnp.pad(z, ((0, 0), (L0 - 1, L0 - 1), (L1 - 1, L1 - 1)))
        for i, d0 in enumerate(range(-(L0 - 1), L0)):
            for j, d1 in enumerate(range(-(L1 - 1), L1)):
                shifted = zp[
                    :,
                    L0 - 1 + d0 : L0 - 1 + d0 + vdims[0],
                    L1 - 1 + d1 : L1 - 1 + d1 + vdims[1],
                ]
                out = out.at[:, :, i, j].set(jnp.einsum("kab,jab->kj", z, shifted))
    else:
        raise ValueError(f"unsupported spatial rank {len(ldims)}")
    return out


def psi_ref(z, x, ldims):
    """psi[k][p, l] = sum_u Z_k[u] X[p, u + l]."""
    k = z.shape[0]
    p = x.shape[0]
    vdims = z.shape[1:]
    out = jnp.zeros((k, p) + tuple(ldims), dtype=z.dtype)
    if len(ldims) == 1:
        (L,) = ldims
        for li in range(L):
            win = x[:, li : li + vdims[0]]
            out = out.at[:, :, li].set(jnp.einsum("kt,pt->kp", z, win))
    elif len(ldims) == 2:
        L0, L1 = ldims
        for li in range(L0):
            for lj in range(L1):
                win = x[:, li : li + vdims[0], lj : lj + vdims[1]]
                out = out.at[:, :, li, lj].set(jnp.einsum("kab,pab->kp", z, win))
    else:
        raise ValueError(f"unsupported spatial rank {len(ldims)}")
    return out


def dict_grad_ref(phi, psi, d):
    """grad[k,p,l] = sum_{k', tau} phi[k,k'][tau] D[k',p,l-tau] - psi[k,p,l]."""
    k, p = d.shape[0], d.shape[1]
    ldims = d.shape[2:]
    grad = -psi
    if len(ldims) == 1:
        (L,) = ldims
        dp = jnp.pad(d, ((0, 0), (0, 0), (L - 1, L - 1)))
        for i, tau in enumerate(range(-(L - 1), L)):
            # D[k', p, l - tau] for l in [0, L)
            win = dp[:, :, L - 1 - tau : 2 * L - 1 - tau]
            grad = grad + jnp.einsum("kj,jpl->kpl", phi[:, :, i], win)
    elif len(ldims) == 2:
        L0, L1 = ldims
        dp = jnp.pad(d, ((0, 0), (0, 0), (L0 - 1, L0 - 1), (L1 - 1, L1 - 1)))
        for i, t0 in enumerate(range(-(L0 - 1), L0)):
            for j, t1 in enumerate(range(-(L1 - 1), L1)):
                win = dp[
                    :,
                    :,
                    L0 - 1 - t0 : 2 * L0 - 1 - t0,
                    L1 - 1 - t1 : 2 * L1 - 1 - t1,
                ]
                grad = grad + jnp.einsum("kj,jpab->kpab", phi[:, :, i, j], win)
    else:
        raise ValueError(f"unsupported spatial rank {len(ldims)}")
    return grad
