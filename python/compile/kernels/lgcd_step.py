"""Layer-1 Pallas kernel: the LGCD candidate map (eq. 7).

Computes the optimal additive update for every coordinate of a beta
block:

    dZ[k, u] = ST(beta[k, u], lambda) / ||D_k||^2  -  Z[k, u]

This is the per-iteration hot-spot of locally-greedy selection (the
argmax that follows is a cheap reduction done by the caller / L2 graph).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the map is purely
elementwise (VPU work, no MXU), so the tiling goal is bandwidth: each
grid step streams one (1, BLOCK) slab of beta and Z from HBM to VMEM and
writes one slab out. With BLOCK = 4096 f32 lanes the working set per
step is ~48 KiB — far under the ~16 MiB VMEM budget, leaving room for
double-buffering. interpret=True on CPU (Mosaic lowering needs a real
TPU); correctness is checked against ref.lgcd_step_ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# f32 lanes per grid step (multiple of the 8x128 VPU tile).
BLOCK = 4096


def _kernel(beta_ref, z_ref, norms_ref, lam_ref, out_ref):
    beta = beta_ref[...]
    lam = lam_ref[0]
    st = jnp.sign(beta) * jnp.maximum(jnp.abs(beta) - lam, 0.0)
    out_ref[...] = st / norms_ref[0] - z_ref[...]


def lgcd_step(beta, z, norms_sq, lam):
    """Pallas-backed dZ map.

    beta, z  : [K, *spatial]
    norms_sq : [K]
    lam      : scalar array (shape () or (1,))
    returns  : dZ with beta's shape.
    """
    k = beta.shape[0]
    spatial = beta.shape[1:]
    n = 1
    for s in spatial:
        n *= s
    lam = jnp.reshape(lam, (1,)).astype(beta.dtype)

    bflat = beta.reshape(k, n)
    zflat = z.reshape(k, n)
    # Pad the spatial axis to a BLOCK multiple so the grid tiles exactly.
    pad = (-n) % BLOCK
    if pad:
        bflat = jnp.pad(bflat, ((0, 0), (0, pad)))
        zflat = jnp.pad(zflat, ((0, 0), (0, pad)))
    np_ = n + pad

    out = pl.pallas_call(
        _kernel,
        grid=(k, np_ // BLOCK),
        in_specs=[
            pl.BlockSpec((1, BLOCK), lambda ki, ti: (ki, ti)),
            pl.BlockSpec((1, BLOCK), lambda ki, ti: (ki, ti)),
            pl.BlockSpec((1,), lambda ki, ti: (ki,)),
            pl.BlockSpec((1,), lambda ki, ti: (0,)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK), lambda ki, ti: (ki, ti)),
        out_shape=jax.ShapeDtypeStruct((k, np_), beta.dtype),
        interpret=True,
    )(bflat, zflat, norms_sq.astype(beta.dtype), lam)

    return out[:, :n].reshape((k,) + spatial)
